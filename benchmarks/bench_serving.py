"""Serving impact (beyond-paper, §4 motivation): what does ProD-quality length
prediction buy the scheduler?

Tracks:

* ``run``            — single replica, head TRAINED on scenario features:
  FCFS/max-reserve (vLLM-naive) vs ProD-driven SJF + quantile reservation vs
  the oracle upper bound, under a KV-memory-bound regime.
* ``run_cluster``    — cluster scale: a ≥50k-request heavy-tailed open-loop
  trace (all eight model×scenario laws) replayed across N SimEngine replicas
  under router × reservation policies, with the LatentOracle standing in for
  the ProD head. Prints per-policy makespan / p50 / p99 / KV-waste.
* ``run_cluster_hetero`` — heterogeneous fleet × per-class SLOs × work
  stealing.
* ``run_cluster_predictors`` — predictor-in-the-loop: the TRAINED ProD-D
  head (batched jitted inference at dispatch, via ``PredictorService``)
  vs the analytic ``LatentOracle`` vs the zero-error ``PerfectOracle``,
  crossed with FCFS / EDF / least-laxity queue orderings under SLOs.
* ``run_cluster_adaptation`` — closed-loop online adaptation: static vs
  adaptive-conformal vs conformal+refresh serving of the trained head,
  on a stationary vs a drifting trace, with SLO-aware admission. Shows the
  static head's reservation coverage collapsing under drift while the
  adapted stack holds the target.
* ``run_cluster_prefix`` — shared-context traffic (system prompts +
  multi-turn chat sessions + agentic loops) replayed with ref-counted
  prefix sharing off/on × {jsq, prefix_affine} routing. Reports KV
  amplification (logical tokens served per physical token reserved) and
  prefill ticks erased by prefix cache hits.
* ``run_cluster_refine`` — mid-flight posterior refinement: the dispatch
  histogram frozen for the request's lifetime (prompt-only) vs re-conditioned
  on survival every ``refine_every`` ticks (truncate-renorm) vs additionally
  hazard-corrected by a learned table, crossed with SRTF+preempt / laxity
  orderings in a KV-bound regime. Reports remaining-work MAE by decode
  progress plus the p99/SLO wins (and KV-capacity cost) of refreshed keys
  and repriced reservations.

    PYTHONPATH=src python benchmarks/bench_serving.py [--cluster-only]

``--stamp BENCH_serving.json`` writes every table's rows + validation
checks (plus run metadata) to a JSON file, starting the perf trajectory
the ROADMAP asks for.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.serving.adaptation import (AdaptationConfig, AdmissionController,
                                      OnlineAdapter, coverage_of)
from repro.serving.arrivals import (DriftSpec, LatentOracle, TraceConfig,
                                    make_trace, mean_true_length, stable_rate,
                                    stable_rate_specs)
from repro.serving.cluster import Cluster
from repro.serving.engine import ReplicaSpec, SimEngine
from repro.serving.predictor import (PerfectOracle, PredictorService,
                                     fit_trace_head)
from repro.serving.request import workload_from_scenario
from repro.serving.scheduler import Policy
from repro.serving.telemetry import Tracer


def make_oracle(cfg: TraceConfig) -> LatentOracle:
    """Shared LatentOracle construction seam for every cluster table.

    The oracle reads each request's noise-corrupted latents directly, so its
    only coupling to ``cfg`` is implicit (the trace's ``view`` noise); keeping
    one factory makes that coupling — and any future oracle configuration —
    a single-line change instead of N copies."""
    del cfg  # trace-level coupling is carried by the requests' features
    return LatentOracle()

POLICIES = (
    Policy("fcfs", "max", max_seq_len=2048),
    Policy("fcfs", "predicted", max_seq_len=2048),
    Policy("sjf_pred", "predicted", max_seq_len=2048),
    Policy("sjf_pred", "quantile", quantile=0.9, max_seq_len=2048),
    Policy("srtf_pred", "quantile", quantile=0.9, max_seq_len=2048,
           preempt=True),
    Policy("sjf_oracle", "oracle", max_seq_len=2048),
)


def run(model="qwen", scen="chat", n_requests=250, fast=True, seed=0,
        verbose=True):
    import jax
    import jax.numpy as jnp

    try:
        from benchmarks.common import scenario_pcfg
    except ImportError:       # invoked as a script: benchmarks/ is sys.path[0]
        from common import scenario_pcfg
    from repro.core import bins as B
    from repro.core import targets as T
    from repro.core.predictor import train_predictor
    from repro.data import make_scenario

    data = make_scenario(model, scen, n_train=800 if fast else None,
                         n_test=max(400, n_requests), seed=seed,
                         full_paper_splits=not fast)
    pcfg = scenario_pcfg(data, epochs=15 if fast else 30)
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.dist_target(jnp.asarray(data.len_train, jnp.float32), edges)
    pred = train_predictor(jax.random.PRNGKey(seed),
                           jnp.asarray(data.phi_train["last"]), tgt, pcfg, edges)
    reqs = workload_from_scenario(data, n_requests, seed=seed, arrival_rate=3.0)
    # memory-bound regime: budget ~8 full reservations
    kv_budget = 8 * (128 + 2048)
    rows = []
    for pol in POLICIES:
        st = SimEngine(max_slots=64, kv_budget=kv_budget, policy=pol,
                       predictor=pred).run(reqs)
        rows.append(st.row())
        if verbose:
            print(f"  {st.policy:24s} lat={st.mean_latency:9.1f} "
                  f"p90={st.p90_latency:9.1f} thr={st.throughput:6.2f} "
                  f"waste={st.kv_waste_ratio:.3f} ovf={st.overflow_events} "
                  f"peak={st.peak_reserved}")
    return rows


def validate(rows) -> dict:
    by = {r["policy"]: r for r in rows}
    naive = by["fcfs+max"]
    prod = by["sjf_pred+quantile"]
    srtf = by.get("srtf_pred+quantile", prod)
    oracle = by["sjf_oracle+oracle"]
    return {
        "prod_beats_naive_latency": prod["mean_latency"] < naive["mean_latency"],
        "prod_latency_gain_pct": 100 * (naive["mean_latency"] - prod["mean_latency"])
        / naive["mean_latency"],
        "prod_reduces_waste": prod["kv_waste_ratio"] < naive["kv_waste_ratio"],
        "oracle_is_bound": oracle["mean_latency"] <= prod["mean_latency"] * 1.05,
        "prod_throughput_gain_pct": 100 * (prod["throughput"] - naive["throughput"])
        / max(naive["throughput"], 1e-9),
        "srtf_not_worse_than_sjf": srtf["mean_latency"]
        <= prod["mean_latency"] * 1.05,
        "srtf_preemptions": srtf.get("preemptions", 0),
    }


# ---------------------------------------------------------------------------
# cluster scale: router × reservation matrix over a heavy-tailed open trace
# ---------------------------------------------------------------------------

CLUSTER_MATRIX = (
    # (router, policy) — round_robin+max is the prediction-blind baseline;
    # psq + quantile is the full ProD-aware stack (predicted-shortest-queue
    # dispatch + distributional-quantile KV reservation)
    ("round_robin", Policy("fcfs", "max", max_seq_len=4096)),
    ("round_robin", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096)),
    ("least_kv", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096)),
    ("jsq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096)),
    ("psq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096)),
)


def run_cluster(n_requests=50_000, n_replicas=4, max_slots=32,
                pattern="bursty", load=0.7, seed=0, verbose=True):
    """Replay one heavy-tailed mixed-scenario trace under every
    router × reservation policy. The arrival rate is set from the trace's own
    mean length so the quantile-reservation cluster runs at ``load``
    utilization — the max-reserve baseline is then structurally overloaded,
    which is exactly the regime the paper's predictions pay off in."""
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), load)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                      model="mix", scenario="mix", seed=seed)
    t0 = time.time()
    reqs = make_trace(cfg)
    if not reqs:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    if verbose:
        print(f"trace: {n_requests} requests ({pattern}, rate {rate:.3f}/step,"
              f" mean len {mean_true_length(reqs):.0f},"
              f" max len {max(r.true_len for r in reqs)})"
              f" built in {time.time() - t0:.1f}s")
        print(f"  {'router':12s} {'policy':20s} {'makespan':>9s} {'p50':>8s} "
              f"{'p99':>9s} {'waste':>6s} {'ovf':>6s} {'bal':>5s} {'secs':>6s}")
    kv_budget = 8 * (256 + 4096)     # per replica: 8 full max-reservations
    oracle = make_oracle(cfg)
    rows = []
    for router, pol in CLUSTER_MATRIX:
        t0 = time.time()
        st = Cluster.uniform(n_replicas, max_slots, kv_budget, pol,
                             router=router, predictor=oracle).run(reqs)
        dt = time.time() - t0
        row = st.row()
        row["seconds"] = dt
        rows.append(row)
        if verbose:
            print(f"  {st.router:12s} {st.policy:20s} {st.makespan:9.0f} "
                  f"{st.p50_latency:8.1f} {st.p99_latency:9.1f} "
                  f"{st.kv_waste_ratio:6.3f} {st.overflow_events:6d} "
                  f"{st.balance:5.2f} {dt:6.1f}")
    return rows


def validate_cluster(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["router"], r["policy"]): r for r in rows}
    naive = by[("round_robin", "fcfs+max")]
    prod = by[("psq", "fcfs+quantile")]
    return {
        "all_completed": all(r["completed"] == rows[0]["completed"]
                             for r in rows),
        "prod_beats_naive_p99": prod["p99_latency"] < naive["p99_latency"],
        "prod_p99_gain_x": naive["p99_latency"]
        / max(prod["p99_latency"], 1e-9),
        "prod_reduces_waste": prod["kv_waste_ratio"] < naive["kv_waste_ratio"],
        "replay_seconds_max": max(r["seconds"] for r in rows),
        "replay_under_60s": all(r["seconds"] < 60.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# heterogeneous fleet × SLO × work stealing
# ---------------------------------------------------------------------------

def hetero_specs(max_slots=32) -> tuple:
    """2 fast large replicas + 2 slow small ones (half the slots/KV, 1/2 the
    decode speed) — the mixed-fleet regime where load-blind routing breaks."""
    kv_fast = 8 * (256 + 4096)
    return (
        ReplicaSpec(max_slots, kv_fast, speed=2, prefill_tokens_per_step=256),
        ReplicaSpec(max_slots, kv_fast, speed=2, prefill_tokens_per_step=256),
        ReplicaSpec(max_slots // 2, kv_fast // 2, speed=1,
                    prefill_tokens_per_step=128),
        ReplicaSpec(max_slots // 2, kv_fast // 2, speed=1,
                    prefill_tokens_per_step=128),
    )


HETERO_MATRIX = (
    # (router, policy, rebalance_every, steal) — the load/speed-blind
    # round_robin baseline vs increasingly prediction-aware stacks, ending in
    # the full ProD stack: psq dispatch + quantile reservation + ProD-aware
    # quantile work stealing
    ("round_robin", Policy("fcfs", "max", max_seq_len=4096), 0, "tail"),
    ("round_robin", Policy("fcfs", "quantile", quantile=0.9,
                           max_seq_len=4096), 0, "tail"),
    ("jsq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096),
     0, "tail"),
    ("psq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096),
     0, "tail"),
    ("psq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096),
     100, "tail"),
    ("psq", Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096),
     100, "quantile"),
)


def run_cluster_hetero(n_requests=50_000, max_slots=32, pattern="bursty",
                       load=0.8, slo_factor=8.0, slo_floor=200.0, seed=0,
                       verbose=True):
    """Heterogeneous 4-replica fleet under per-class SLOs: router ×
    reservation × work-stealing matrix over one heavy-tailed trace. The
    arrival rate targets ``load`` of the fleet's speed-weighted decode
    capacity, so speed-blind dispatch structurally overloads the slow
    replicas — the regime where prediction-aware routing + stealing pays."""
    specs = hetero_specs(max_slots)
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate_specs(specs, mean_true_length(probe), load)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                      model="mix", scenario="mix", seed=seed,
                      slo_factor=slo_factor, slo_floor=slo_floor)
    t0 = time.time()
    reqs = make_trace(cfg)
    if not reqs:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    if verbose:
        print(f"hetero trace: {n_requests} requests ({pattern}, "
              f"rate {rate:.3f}/step, mean len {mean_true_length(reqs):.0f}, "
              f"SLO = arrival + {slo_floor:.0f} + {slo_factor:.0f}x class "
              f"median) built in {time.time() - t0:.1f}s")
        print(f"  specs: 2x(slots={max_slots},speed=2) + "
              f"2x(slots={max_slots // 2},speed=1), prefill modeled")
        print(f"  {'router':12s} {'policy':16s} {'steal':>12s} {'p50':>8s} "
              f"{'p99':>9s} {'viol':>6s} {'t/o':>6s} {'goodput':>8s} "
              f"{'stolen':>7s} {'secs':>6s}")
    oracle = make_oracle(cfg)
    rows = []
    for router, pol, reb, steal in HETERO_MATRIX:
        t0 = time.time()
        st = Cluster(specs, pol, router=router, predictor=oracle,
                     rebalance_every=reb, steal=steal).run(reqs)
        dt = time.time() - t0
        row = st.row()
        row["seconds"] = dt
        row["rebalance_every"] = reb
        row["steal"] = steal if reb else "off"
        rows.append(row)
        if verbose:
            label = f"{steal}@{reb}" if reb else "off"
            print(f"  {st.router:12s} {st.policy:16s} {label:>12s} "
                  f"{st.p50_latency:8.1f} {st.p99_latency:9.1f} "
                  f"{st.slo_violations:6d} {st.timed_out:6d} "
                  f"{st.goodput:8.2f} {st.stolen:7d} {dt:6.1f}")
    return rows


def validate_cluster_hetero(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["router"], r["policy"], r["steal"]): r for r in rows}
    naive = by[("round_robin", "fcfs+max", "off")]
    prod = by[("psq", "fcfs+quantile", "quantile")]

    def bad(r):
        return r["slo_violations"] + r["timed_out"]

    return {
        "prod_steal_beats_rr_p99": prod["p99_latency"] < naive["p99_latency"],
        "prod_steal_beats_rr_slo": bad(prod) < bad(naive),
        "prod_p99_gain_x": naive["p99_latency"]
        / max(prod["p99_latency"], 1e-9),
        "prod_slo_gain_x": bad(naive) / max(bad(prod), 1e-9),
        "prod_goodput_gain_x": prod["goodput"]
        / max(naive["goodput"], 1e-9),
        "stealing_used": prod["stolen"] > 0,
        "replay_under_60s": all(r["seconds"] < 60.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# predictor-in-the-loop: trained ProD-D head vs oracle proxies × orderings
# ---------------------------------------------------------------------------

ORDER_MATRIX = ("fcfs", "edf", "laxity")


def run_cluster_predictors(n_requests=50_000, n_replicas=4, max_slots=32,
                           pattern="bursty", load=0.7, slo_factor=10.0,
                           slo_floor=300.0, seed=0, n_train=4000,
                           verbose=True):
    """The paper's head in the serving path: replay one SLO-carrying trace
    under predictor ∈ {LatentOracle (analytic proxy), trained ProD-D head
    (batched jitted dispatch-time inference), PerfectOracle (upper bound)}
    × ordering ∈ {fcfs, edf, laxity}, all with psq routing + q0.9 quantile
    reservation. The trained head is fit on repeated-generation targets from
    the same calibrated laws (never on the served trace)."""
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), load)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                      model="mix", scenario="mix", seed=seed,
                      slo_factor=slo_factor, slo_floor=slo_floor)
    t0 = time.time()
    reqs = make_trace(cfg)
    if not reqs:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    t_trace = time.time() - t0
    t0 = time.time()
    head = fit_trace_head(cfg, n_train=n_train, r=16, seed=seed + 7)
    t_train = time.time() - t0
    if verbose:
        print(f"predictor trace: {n_requests} requests ({pattern}, rate "
              f"{rate:.3f}/step, SLO = arrival + {slo_floor:.0f} + "
              f"{slo_factor:.0f}x class median) built in {t_trace:.1f}s; "
              f"ProD-D head trained on {n_train}x16 repeated draws "
              f"in {t_train:.1f}s")
        print(f"  {'predictor':14s} {'order':8s} {'p50':>8s} {'p99':>9s} "
              f"{'viol':>6s} {'t/o':>6s} {'goodput':>8s} {'waste':>6s} "
              f"{'secs':>6s}")
    kv_budget = 8 * (256 + 4096)
    predictors = (
        ("latent-oracle", lambda: make_oracle(cfg)),
        ("trained-prod-d", lambda: PredictorService(head, window=16.0)),
        ("perfect", lambda: PerfectOracle()),
    )
    rows = []
    for pname, make_pred in predictors:
        for order in ORDER_MATRIX:
            pol = Policy(order, "quantile", quantile=0.9, max_seq_len=4096)
            pred = make_pred()
            t0 = time.time()
            st = Cluster.uniform(n_replicas, max_slots, kv_budget, pol,
                                 router="psq", predictor=pred).run(reqs)
            dt = time.time() - t0
            row = st.row()
            row.update(predictor=pname, order=order, seconds=dt)
            if isinstance(pred, PredictorService):
                row["service"] = pred.stats.row()
            rows.append(row)
            if verbose:
                print(f"  {pname:14s} {order:8s} {st.p50_latency:8.1f} "
                      f"{st.p99_latency:9.1f} {st.slo_violations:6d} "
                      f"{st.timed_out:6d} {st.goodput:8.2f} "
                      f"{st.kv_waste_ratio:6.3f} {dt:6.1f}")
    if verbose:
        srow = next(r["service"] for r in rows if "service" in r)
        print(f"  service: {srow['batches']} fused batches, mean batch "
              f"{srow['mean_batch']:.1f}, hit rate {srow['hit_rate']:.3f}, "
              f"buckets {srow['buckets']}")
    return rows


def validate_cluster_predictors(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["predictor"], r["order"]): r for r in rows}

    def bad(r):
        return r["slo_violations"] + r["timed_out"]

    trained_f = by[("trained-prod-d", "fcfs")]
    trained_l = by[("trained-prod-d", "laxity")]
    trained_e = by[("trained-prod-d", "edf")]
    oracle_f = by[("latent-oracle", "fcfs")]
    perfect_f = by[("perfect", "fcfs")]
    deadline_best = min(bad(trained_e), bad(trained_l))
    srow = trained_f.get("service", {})
    return {
        "trained_head_in_loop": srow.get("batches", 0) > 0,
        "service_mean_batch": srow.get("mean_batch", 0.0),
        "perfect_is_bound_p99": perfect_f["p99_latency"]
        <= trained_f["p99_latency"] * 1.05,
        "trained_within_2x_oracle_p99": trained_f["p99_latency"]
        <= 2.0 * oracle_f["p99_latency"],
        "trained_p99_vs_oracle_x": trained_f["p99_latency"]
        / max(oracle_f["p99_latency"], 1e-9),
        "deadline_order_cuts_slo_misses": deadline_best < bad(trained_f),
        "deadline_slo_gain_x": bad(trained_f) / max(deadline_best, 1e-9),
        "replay_under_90s": all(r["seconds"] < 90.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# preemption modes: recompute vs keep-pages, over orderings x quantiles
# ---------------------------------------------------------------------------

PREEMPTION_MATRIX = tuple(
    # (order, preempt_mode, quantile, preempt) — the paired recompute/keep
    # rows differ ONLY in what happens to a victim's KV reservation, so the
    # delta is exactly the partial-reservation handoff; the preempt=False
    # pair is the no-regression control (modes must be bit-identical there)
    (order, mode, q, True)
    for order in ("srtf_pred", "laxity")
    for q in (0.75, 0.9)
    for mode in ("recompute", "keep")
) + (("srtf_pred", "recompute", 0.9, False),
     ("srtf_pred", "keep", 0.9, False))


def run_cluster_preemption(n_requests=50_000, n_replicas=4, max_slots=32,
                           pattern="bursty", load=0.55, page_size=16,
                           seed=0, verbose=True):
    """Keep-pages vs recompute preemption at equal KV budget: replay one
    heavy-tailed trace under srtf/laxity preemptive orderings × reservation
    quantiles × ``preempt_mode``, on a paged (``page_size``-token) KV pool
    with an expensive prefill (so a recompute-mode resume visibly re-pays
    ceil((prompt+progress)/rate) ticks that keep mode skips). The load is
    feasible — every request completes — so the latency columns isolate the
    recompute waste instead of saturating at the SLO deadline."""
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), load)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                      model="mix", scenario="mix", seed=seed,
                      slo_factor=30.0, slo_floor=2000.0)
    t0 = time.time()
    reqs = make_trace(cfg)
    if not reqs:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    kv_budget = (8 * (256 + 4096)) // page_size * page_size
    specs = (ReplicaSpec(max_slots, kv_budget, speed=1,
                         prefill_tokens_per_step=8,
                         page_size=page_size),) * n_replicas
    if verbose:
        print(f"preemption trace: {n_requests} requests ({pattern}, rate "
              f"{rate:.3f}/step) built in {time.time() - t0:.1f}s; "
              f"page_size={page_size}, kv={kv_budget}/replica, prefill "
              f"8 tok/tick")
        print(f"  {'order':10s} {'mode':10s} {'q':>5s} {'preempt':>8s} "
              f"{'p50':>8s} {'p99':>9s} {'recomp':>7s} {'heldpk':>7s} "
              f"{'occ':>6s} {'frag':>6s} {'secs':>6s}")
    oracle = make_oracle(cfg)
    rows = []
    for order, mode, q, preempt in PREEMPTION_MATRIX:
        pol = Policy(order, "quantile", quantile=q, max_seq_len=4096,
                     preempt=preempt, preempt_factor=1.2, preempt_mode=mode)
        t0 = time.time()
        st = Cluster(specs, pol, router="psq", predictor=oracle).run(reqs)
        dt = time.time() - t0
        row = st.row()
        row.update(order=order, mode=mode, quantile=q, preempt=preempt,
                   seconds=dt)
        rows.append(row)
        if verbose:
            print(f"  {order:10s} {mode:10s} {q:5.2f} {str(preempt):>8s} "
                  f"{st.p50_latency:8.1f} {st.p99_latency:9.1f} "
                  f"{st.recompute_ticks:7d} {st.held_peak:7d} "
                  f"{st.occupancy:6.3f} {st.frag_ratio:6.4f} {dt:6.1f}")
    return rows


def validate_cluster_preemption(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["order"], r["mode"], r["quantile"], r["preempt"]): r
          for r in rows}
    pairs = [((o, "recompute", q, True), (o, "keep", q, True))
             for o in ("srtf_pred", "laxity") for q in (0.75, 0.9)]
    recomp_cut = all(by[k]["recompute_ticks"] > by[kk]["recompute_ticks"]
                     for k, kk in pairs)
    # headline claim: strict p99 reduction on the srtf pairs (the classic
    # SRTF-churn regime); the laxity pairs must stay within noise (5%)
    p99_srtf = all(by[("srtf_pred", "keep", q, True)]["p99_latency"]
                   < by[("srtf_pred", "recompute", q, True)]["p99_latency"]
                   for q in (0.75, 0.9))
    p99_not_worse = all(by[kk]["p99_latency"] <= by[k]["p99_latency"] * 1.05
                        for k, kk in pairs)
    base = by[("srtf_pred", "recompute", 0.9, True)]
    keep = by[("srtf_pred", "keep", 0.9, True)]
    # preempt=False control: the mode knob must be completely inert
    off_a = dict(by[("srtf_pred", "recompute", 0.9, False)])
    off_b = dict(by[("srtf_pred", "keep", 0.9, False)])
    for d in (off_a, off_b):
        for k in ("seconds", "mode"):
            d.pop(k, None)
    return {
        "preemptions_exercised": all(
            by[k]["preemptions"] > 0 for k, _ in pairs),
        "keep_cuts_recompute_ticks": recomp_cut,
        "recompute_ticks_saved": base["recompute_ticks"]
        - keep["recompute_ticks"],
        "keep_p99_reduced_srtf": p99_srtf,
        "keep_p99_srtf_gain_pct": 100 * (base["p99_latency"]
                                         - keep["p99_latency"])
        / max(base["p99_latency"], 1e-9),
        "keep_p99_within_5pct_everywhere": p99_not_worse,
        "keep_mean_latency_gain": base["mean_latency"] - keep["mean_latency"],
        "keep_holds_pages": keep["held_peak"] > 0,
        # conservation, not equality: at 50k a handful of SLO timeouts may
        # land differently per row, but nothing may vanish and the load must
        # stay feasible (≥ 99.5% completion everywhere)
        "all_accounted": len({r["completed"] + r["timed_out"] + r["dropped"]
                              + r["rejected"] for r in rows}) == 1,
        "completion_rate_min": min(
            r["completed"] / (r["completed"] + r["timed_out"] + r["dropped"]
                              + r["rejected"]) for r in rows),
        "load_feasible": all(
            r["completed"] >= 0.995 * (r["completed"] + r["timed_out"]
                                       + r["dropped"] + r["rejected"])
            for r in rows),
        "no_regression_when_preempt_off": off_a == off_b,
        "replay_under_90s": all(r["seconds"] < 90.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# prefix sharing: session traffic x {sharing off/on} x {jsq, prefix_affine}
# ---------------------------------------------------------------------------

PREFIX_MATRIX = (
    # (router, share_prefixes) — the share=False row is the PR-5 pool (every
    # request pays for its full context privately); the share=True rows add
    # ref-counted prefix pages, and the router axis isolates what affinity
    # placement buys on top of the pool mechanism itself
    ("jsq", False),
    ("jsq", True),
    ("prefix_affine", True),
)


def run_cluster_prefix(n_requests=50_000, n_replicas=4, max_slots=16,
                       load=0.6, seed=0, verbose=True):
    """Shared-context serving: a single-setting trace where every request
    carries a 512-token system prompt and ~2/3 of traffic arrives as
    multi-turn chat sessions / agentic loops whose later turns extend earlier
    context, replayed with the KV pool's ref-counted prefix sharing off vs on
    × {jsq, prefix_affine} routing. ``n_requests`` is the *base* request
    count — session turns append on top (~2.1x total). Reports the KV
    amplification (logical tokens served per physical token reserved),
    prefill ticks actually paid vs erased by prefix hits, and the usual
    latency columns."""
    base = dict(n_requests=n_requests, model="qwen", scenario="math",
                seed=seed, session_frac=0.30, agentic_frac=0.35,
                system_prompt_len=512, session_gap_mean=60.0,
                agentic_gap_mean=2.0, session_turns_mean=3.0,
                agentic_turns_mean=6.0, prompt_min=16, prompt_max=48,
                max_seq_len=1280)
    if n_requests <= 0:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    probe = make_trace(TraceConfig(rate=1.0, **base))
    specs = tuple(ReplicaSpec(max_slots=max_slots, kv_budget=32_768,
                              page_size=16, prefill_tokens_per_step=64)
                  for _ in range(n_replicas))
    rate = stable_rate_specs(specs, mean_true_length(probe), load)
    cfg = TraceConfig(rate=rate, **base)
    t0 = time.time()
    reqs = make_trace(cfg)
    n_sess = sum(1 for r in reqs if r.prefix_id
                 and not r.prefix_id.startswith("sys/"))
    if verbose:
        print(f"prefix trace: {len(reqs)} requests ({n_requests} base + "
              f"{len(reqs) - n_requests} session turns, {n_sess} carrying "
              f"session context, rate {rate:.3f}/step, 512-token system "
              f"prompt) built in {time.time() - t0:.1f}s")
        print(f"  {'router':14s} {'share':>5s} {'p50':>8s} {'p99':>9s} "
              f"{'amp':>6s} {'prefill':>8s} {'saved':>8s} {'hits':>7s} "
              f"{'cow':>5s} {'secs':>6s}")
    pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=1280)
    oracle = make_oracle(cfg)
    rows = []
    for router, share in PREFIX_MATRIX:
        sspecs = tuple(ReplicaSpec(
            max_slots=s.max_slots, kv_budget=s.kv_budget,
            page_size=s.page_size,
            prefill_tokens_per_step=s.prefill_tokens_per_step,
            share_prefixes=share) for s in specs)
        t0 = time.time()
        st = Cluster(sspecs, pol, router=router, predictor=oracle).run(reqs)
        dt = time.time() - t0
        row = st.row()
        row.update(share=share, seconds=dt)
        rows.append(row)
        if verbose:
            print(f"  {st.router:14s} {int(share):5d} {st.p50_latency:8.1f} "
                  f"{st.p99_latency:9.1f} {st.kv_amplification:6.3f} "
                  f"{st.prefill_ticks:8d} {st.prefill_saved_ticks:8d} "
                  f"{st.prefix_hits:7d} {st.cow_copies:5d} {dt:6.1f}")
    return rows


def validate_cluster_prefix(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["router"], r["share"]): r for r in rows}
    off = by[("jsq", False)]
    jsq = by[("jsq", True)]
    aff = by[("prefix_affine", True)]
    return {
        "all_completed": all(r["completed"] == rows[0]["completed"]
                             for r in rows),
        # sharing off must be inert: the pool reports no amplification
        "off_is_inert": off["kv_amplification"] == 1.0
        and off["prefill_saved_ticks"] == 0,
        # acceptance: >1.2x KV-capacity amplification under session traffic
        "amplification_x": aff["kv_amplification"],
        "amplification_over_1_2": aff["kv_amplification"] > 1.2,
        # ... with a measurable prefill reduction (>=10% of the ticks the
        # sharing-off pool pays)
        "prefill_saved_pct": 100 * aff["prefill_saved_ticks"]
        / max(off["prefill_ticks"], 1),
        "prefill_reduced": aff["prefill_saved_ticks"]
        >= 0.10 * off["prefill_ticks"],
        # ... and affinity placement beats jsq on both axes
        "affine_beats_jsq_amp": aff["kv_amplification"]
        > jsq["kv_amplification"],
        "affine_beats_jsq_saved": aff["prefill_saved_ticks"]
        > jsq["prefill_saved_ticks"],
        "affine_p99_not_worse": aff["p99_latency"]
        <= jsq["p99_latency"] * 1.05,
        "replay_under_90s": all(r["seconds"] < 90.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# chunked prefill: TTFT vs throughput under a per-step token budget
# ---------------------------------------------------------------------------

CHUNKED_MATRIX = (
    # (label, order, chunk_order, prefill_chunk_tokens) — all four rows run
    # the SAME per-step token budget. "atomic" is the non-chunked baseline
    # (a prefilling tick dedicates the whole budget to one prompt; decode
    # pauses); the chunked rows stream prompts in chunks interleaved with
    # decode. The last row is the ProD-aware stack: predicted-short-first
    # admission AND predicted-short-first chunk allocation.
    ("atomic", "fcfs", "fcfs", 0),
    ("chunked", "fcfs", "fcfs", 32),
    ("chunked", "fcfs", "prod", 32),       # chunk_order knob in isolation
    ("chunked", "sjf_pred", "prod", 32),   # full ProD-aware stack
)


def run_cluster_chunked(n_requests=50_000, n_replicas=4, max_slots=16,
                        load=0.9, seed=0, budget=96, chunk=32, verbose=True):
    """Chunked prefill under a shared per-step token budget: TTFT vs
    throughput. One heavy-tailed mixed-scenario trace replayed at the same
    ``step_token_budget`` across :data:`CHUNKED_MATRIX`. Slot decode speed
    is the binding resource at ``load``; the budget binds on ticks where
    prompts stream in, which is exactly where chunking and the
    ``chunk_order`` knob act. Reports the TTFT percentiles the chunked
    engine records per request."""
    base = dict(n_requests=n_requests, model="mix", scenario="mix",
                seed=seed, prompt_min=64, prompt_max=512,
                slo_factor=40.0, slo_floor=8000.0)
    if n_requests <= 0:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    probe = make_trace(TraceConfig(rate=1.0, **dict(base, n_requests=2000)))
    ml = mean_true_length(probe)
    mp = float(np.mean([r.prompt_len for r in probe]))
    speed = 2
    # per-slot service time: chunked prefill ticks + decode ticks; rate puts
    # the slot pool (the binding resource) at `load` utilization
    service = mp / chunk + ml / speed
    rate = load * n_replicas * max_slots / service
    cfg = TraceConfig(rate=rate, **base)
    t0 = time.time()
    reqs = make_trace(cfg)
    if verbose:
        print(f"chunked trace: {len(reqs)} requests (rate {rate:.3f}/step, "
              f"mean len {ml:.0f}, mean prompt {mp:.0f}, budget {budget}, "
              f"chunk {chunk}) built in {time.time() - t0:.1f}s")
        print(f"  {'mode':8s} {'order':9s} {'chunks':6s} {'meanTTFT':>9s} "
              f"{'p50TTFT':>8s} {'p99TTFT':>9s} {'thr':>7s} {'p99lat':>9s} "
              f"{'secs':>6s}")
    oracle = make_oracle(cfg)
    rows = []
    for label, order, corder, ck in CHUNKED_MATRIX:
        pol = Policy(order, "quantile", quantile=0.9, max_seq_len=4096,
                     chunk_order=corder)
        specs = tuple(ReplicaSpec(max_slots=max_slots, kv_budget=65_536,
                                  page_size=16, speed=speed,
                                  step_token_budget=budget,
                                  prefill_chunk_tokens=ck)
                      for _ in range(n_replicas))
        t0 = time.time()
        st = Cluster(specs, pol, router="jsq", predictor=oracle).run(reqs)
        dt = time.time() - t0
        row = st.row()
        row.update(mode=label, chunk_order=corder, chunk=ck, seconds=dt)
        rows.append(row)
        if verbose:
            print(f"  {label:8s} {order:9s} {corder:6s} {st.mean_ttft:9.1f} "
                  f"{st.p50_ttft:8.1f} {st.p99_ttft:9.1f} "
                  f"{st.throughput:7.2f} {st.p99_latency:9.1f} {dt:6.1f}")
    return rows


def validate_cluster_chunked(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["mode"], r["policy"].split("+")[0], r["chunk_order"]): r
          for r in rows}
    atomic = by[("atomic", "fcfs", "fcfs")]
    cf = by[("chunked", "fcfs", "fcfs")]
    ck = by[("chunked", "fcfs", "prod")]
    cp = by[("chunked", "sjf_pred", "prod")]
    n = rows[0]["completed"] + rows[0]["timed_out"] + rows[0]["dropped"] \
        + rows[0]["rejected"]
    return {
        "all_accounted": all(
            r["completed"] + r["timed_out"] + r["dropped"] + r["rejected"]
            == n for r in rows),
        # acceptance: the ProD-aware chunked stack beats the atomic FCFS
        # baseline on p99 TTFT by >2x at equal-or-better throughput
        "prod_chunked_beats_fcfs_atomic_p99_ttft":
            cp["p99_ttft"] < 0.5 * atomic["p99_ttft"],
        "p99_ttft_gain_x": atomic["p99_ttft"] / max(cp["p99_ttft"], 1e-9),
        # ... and beats FCFS chunk allocation on mean TTFT (SJF-on-chunks
        # pulls short answers' first tokens forward) at equal throughput
        "prod_beats_fcfs_chunked_mean_ttft":
            cp["mean_ttft"] < cf["mean_ttft"],
        "mean_ttft_gain_pct":
            100.0 * (1.0 - cp["mean_ttft"] / max(cf["mean_ttft"], 1e-9)),
        "throughput_equal": cp["throughput"] >= 0.97 * cf["throughput"]
        and cp["throughput"] >= atomic["throughput"],
        # the chunk_order knob alone (fcfs admission) must not cost
        # throughput; its mean-TTFT delta is reported, not gated (at fcfs
        # admission the ordering only reshuffles within-tick budget)
        "chunk_order_only_mean_ttft_delta_pct":
            100.0 * (1.0 - ck["mean_ttft"] / max(cf["mean_ttft"], 1e-9)),
        "chunk_order_only_throughput_ok":
            ck["throughput"] >= 0.97 * cf["throughput"],
        "chunking_throughput_not_worse":
            cf["throughput"] >= atomic["throughput"],
        "replay_under_90s": all(r["seconds"] < 90.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# mid-flight posterior refinement: prompt-only vs truncate-renorm vs hazard
# ---------------------------------------------------------------------------

REFINE_MODES = (
    # (label, refine?, hazard?) — "prompt-only" is the dispatch-time head
    # frozen for the request's lifetime (refine_every=0, the pre-refinement
    # engine bit-exactly); "renorm" re-conditions each active slot's ProD-D
    # histogram on survival (P[L = l | L > t], pure truncate-renormalize)
    # every refine tick; "hazard" additionally applies the learned
    # hazard-rate correction fit from repeated-generation traces.
    ("prompt-only", False, False),
    ("renorm", True, False),
    ("hazard", True, True),
)

REFINE_T_GRID = (16, 32, 64, 128, 256, 512)


def _mae_by_progress(reqs, refiner, t_grid=REFINE_T_GRID) -> list:
    """Remaining-work MAE by decode progress on an annotated trace:
    posterior quantile-0.5 remaining vs the static prompt-only median
    (``max(predicted_len − t, 1)``), over requests still alive at t."""
    out = []
    for t in t_grid:
        alive = [r for r in reqs if r.true_len > t]
        if len(alive) < 50:
            break
        post = float(np.mean(
            [abs((refiner.quantile(r.pred_probs, float(t), 0.5) - t)
                 - (r.true_len - t)) for r in alive]))
        prompt = float(np.mean(
            [abs(max(r.predicted_len - t, 1.0) - (r.true_len - t))
             for r in alive]))
        out.append({"t": t, "alive": len(alive), "posterior_mae": post,
                    "prompt_only_mae": prompt, "posterior_wins":
                    bool(post < prompt)})
    return out


def run_cluster_refine(n_requests=50_000, n_replicas=4, max_slots=16,
                       load=0.97, seed=0, refine_every=128, verbose=True):
    """Mid-flight posterior refinement table: {prompt-only, truncate-renorm,
    learned-hazard} × {SRTF+preempt-keep, least-laxity} on one KV-bound
    heavy-tailed mixed trace served by the trained ProD-D head.

    The regime is chosen so refinement has something to move: at ``load``
    the KV pool (not slots) binds admission, deadlines are tight, and the
    mixed laws generate real over-runners — requests that outlive their
    dispatch quantile and collapse onto the ``max(rem, 1)`` key floor
    without refinement. The table reports where conditioning on survival
    buys p99 / SLO wins (SRTF victim choice and re-queue keys) and what the
    grown posterior reservations cost in throughput; the hazard rows show
    the learned correction shrinking over-reservations (capacity back). A
    ``mae_by_t`` sub-table (held-out trace) measures how fast the posterior
    beats the frozen prompt-only head as decode progresses."""
    import jax

    from repro.core.online import PosteriorRefiner, fit_hazard_table

    if n_requests <= 0:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), load)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern="bursty",
                      model="mix", scenario="mix", seed=seed,
                      slo_factor=6.0, slo_floor=100.0)
    t0 = time.time()
    head = fit_trace_head(cfg, n_train=2000, r=8, n_bins=32, hidden=64,
                          seed=seed + 7)
    t_train = time.time() - t0
    edges = np.asarray(head.edges, np.float64)
    anno_pol = Policy("fcfs", "quantile", quantile=0.9, max_seq_len=4096)
    svc = PredictorService(head, window=16.0)
    # hazard correction: fit on a disjoint repeated-generation trace
    t0 = time.time()
    fit_reqs = make_trace(TraceConfig(n_requests=3000, rate=1.0, model="mix",
                                      scenario="mix", seed=seed + 101))
    svc.annotate(fit_reqs, anno_pol)
    hazard = fit_hazard_table(
        jax.random.PRNGKey(seed + 3),
        np.stack([r.pred_probs for r in fit_reqs]),
        np.array([r.true_len for r in fit_reqs], np.float64), edges)
    t_hazard = time.time() - t0
    refiners = {"renorm": PosteriorRefiner(edges),
                "hazard": PosteriorRefiner(edges, hazard=hazard)}
    # held-out MAE-by-progress table (how fast the posterior wins)
    held = make_trace(TraceConfig(n_requests=3000, rate=1.0, model="mix",
                                  scenario="mix", seed=seed + 202))
    svc.annotate(held, anno_pol)
    mae = {m: _mae_by_progress(held, rz) for m, rz in refiners.items()}
    reqs = make_trace(cfg)
    if verbose:
        print(f"refine trace: {len(reqs)} requests (bursty, rate "
              f"{rate:.3f}/step, KV-bound); head fit {t_train:.1f}s, hazard "
              f"table fit {t_hazard:.1f}s; refine_every={refine_every}")
        for m in refiners:
            won = [r["t"] for r in mae[m] if r["posterior_wins"]]
            print(f"  mae_by_t[{m}]: posterior wins from t={won[0] if won else '-'}"
                  f" (grid {', '.join(str(r['t']) for r in mae[m])})")
        print(f"  {'mode':12s} {'order':10s} {'p50':>7s} {'p99':>9s} "
              f"{'slo':>5s} {'t/o':>5s} {'goodput':>8s} {'thr':>7s} "
              f"{'waste':>6s} {'shrink':>6s} {'grow':>6s} {'secs':>5s}")
    rows = []
    for order in ("srtf_pred", "laxity"):
        for label, refine, use_hazard in REFINE_MODES:
            pol = Policy(order, "quantile", quantile=0.9, max_seq_len=4096,
                         preempt=(order == "srtf_pred"), preempt_factor=1.5,
                         preempt_mode="keep",
                         refine_every=refine_every if refine else 0)
            rz = refiners["hazard" if use_hazard else "renorm"] \
                if refine else None
            specs = tuple(ReplicaSpec(max_slots=max_slots, kv_budget=8192,
                                      page_size=16, speed=2,
                                      prefill_tokens_per_step=64)
                          for _ in range(n_replicas))
            t0 = time.time()
            st = Cluster(specs, pol, router="psq",
                         predictor=PredictorService(head, window=16.0),
                         refiner=rz).run(reqs)
            dt = time.time() - t0
            row = st.row()
            row.update(mode=label, order=order, seconds=dt,
                       mae_by_t=mae.get(label, []))
            rows.append(row)
            if verbose:
                print(f"  {label:12s} {order:10s} {st.p50_latency:7.1f} "
                      f"{st.p99_latency:9.1f} {st.slo_violations:5d} "
                      f"{st.timed_out:5d} {st.goodput:8.2f} "
                      f"{st.throughput:7.2f} {st.kv_waste_ratio:6.3f} "
                      f"{st.refine_shrinks:6d} {st.refine_grows:6d} "
                      f"{dt:5.1f}")
    return rows


def validate_cluster_refine(rows) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["mode"], r["order"]): r for r in rows}
    po_s = by[("prompt-only", "srtf_pred")]
    rn_s = by[("renorm", "srtf_pred")]
    hz_s = by[("hazard", "srtf_pred")]
    po_l = by[("prompt-only", "laxity")]
    hz_l = by[("hazard", "laxity")]
    n = po_s["completed"] + po_s["timed_out"] + po_s["dropped"] \
        + po_s["rejected"]
    mae = rn_s["mae_by_t"]
    wins = [m["t"] for m in mae if m["posterior_wins"]]
    return {
        "all_accounted": all(
            r["completed"] + r["timed_out"] + r["dropped"] + r["rejected"]
            == n for r in rows),
        "refine_exercised": rn_s["refine_events"] > 0
        and hz_s["refine_shrinks"] > 0,
        "prompt_only_is_inert": po_s["refine_events"] == 0,
        # acceptance: the posterior's remaining-work MAE strictly beats the
        # frozen prompt-only head from some progress point on
        "mae_posterior_wins_at_some_t": bool(wins),
        "mae_first_win_t": wins[0] if wins else None,
        "mae_final_gain_pct": 100.0 * (1.0 - mae[-1]["posterior_mae"]
                                       / max(mae[-1]["prompt_only_mae"],
                                             1e-9)) if mae else 0.0,
        # acceptance: refreshed SRTF keys (over-runners become preemptable
        # and re-queue behind genuine shorts) must not cost tail latency
        "posterior_srtf_p99_not_worse":
            rn_s["p99_latency"] <= po_s["p99_latency"]
        and hz_s["p99_latency"] <= po_s["p99_latency"],
        "srtf_p99_gain_pct": 100.0 * (1.0 - rn_s["p99_latency"]
                                      / max(po_s["p99_latency"], 1e-9)),
        # ... and buys an SLO-attainment win on the SRTF row
        "posterior_srtf_slo_win":
            rn_s["slo_violations"] < po_s["slo_violations"],
        # hazard shrinks hand KV capacity back on the laxity row (no
        # preemption churn there, so the reservation effect is isolated):
        # reported plus gated loosely — goodput must not regress
        "hazard_laxity_goodput_not_worse":
            hz_l["goodput"] >= po_l["goodput"],
        "hazard_laxity_goodput_gain_pct":
            100.0 * (hz_l["goodput"] / max(po_l["goodput"], 1e-9) - 1.0),
        # the honest cost: grown posterior reservations eat KV-bound
        # throughput on the SRTF row (reported, not gated)
        "renorm_srtf_goodput_delta_pct":
            100.0 * (rn_s["goodput"] / max(po_s["goodput"], 1e-9) - 1.0),
        "replay_under_120s": all(r["seconds"] < 120.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# online adaptation: static vs conformal vs conformal+refresh, under drift
# ---------------------------------------------------------------------------

ADAPT_MODES = (
    # (label, gamma, refresh?) — "static" runs the identical closed-loop code
    # path with a frozen quantile, so coverage is measured apples-to-apples
    ("static", 0.0, False),
    ("conformal", 0.01, False),
    ("conformal+refresh", 0.01, True),
)


def _coverage_split(cl: Cluster, switch: float) -> tuple:
    """(overall, post-switch) reservation coverage over completed requests
    (see :func:`repro.serving.adaptation.coverage_of` for the semantics)."""
    done = [r for e in cl.engines for r in e.done]
    return coverage_of(done), coverage_of(done, since=switch)


def run_cluster_adaptation(n_requests=50_000, n_replicas=4, max_slots=32,
                           pattern="bursty", load=0.7, slo_factor=10.0,
                           slo_floor=300.0, scale_mult=1.5, seed=0,
                           n_train=4000, target=0.9, verbose=True):
    """Closed-loop adaptation table: serve the trained ProD-D head through an
    ``OnlineAdapter`` in mode ∈ {static (frozen quantile), conformal (ACI on
    the reservation quantile), conformal+refresh (plus periodic warm-start
    re-fits on the completion buffer)} × trace ∈ {stationary, drift}. The
    drift trace abruptly inflates true-length scales by ``scale_mult``
    mid-trace while features stay put — invisible to the fit-time head. All
    rows run SLO-aware admission, so infeasible requests are rejected early
    instead of timing out late. Reports reservation coverage (overall and
    post-switch), p99, SLO misses, rejects, refreshes, and goodput."""
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), load)
    switch = 0.5 * n_requests / rate
    base_cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                           model="mix", scenario="mix", seed=seed,
                           slo_factor=slo_factor, slo_floor=slo_floor)
    import dataclasses
    traces = (
        ("stationary", make_trace(base_cfg)),
        ("drift", make_trace(dataclasses.replace(
            base_cfg,
            drift=DriftSpec(switch_step=switch, scale_mult=scale_mult)))),
    )
    t0 = time.time()
    head = fit_trace_head(base_cfg, n_train=n_train, r=16, seed=seed + 7)
    t_train = time.time() - t0
    makespan_est = n_requests / rate
    if verbose:
        print(f"adaptation traces: {n_requests} requests ({pattern}, rate "
              f"{rate:.3f}/step; drift = x{scale_mult} true-length scale at "
              f"step {switch:.0f}); ProD-D head trained in {t_train:.1f}s; "
              f"coverage target {target}")
        print(f"  {'trace':11s} {'mode':18s} {'cov':>6s} {'cov>sw':>7s} "
              f"{'p99':>9s} {'viol':>6s} {'t/o':>6s} {'rej':>6s} "
              f"{'refit':>5s} {'q_eff':>6s} {'goodput':>8s} {'secs':>6s}")
    kv_budget = 8 * (256 + 4096)
    pol = Policy("fcfs", "quantile", quantile=target, max_seq_len=4096)
    rows = []
    for tname, reqs in traces:
        for label, gamma, refresh in ADAPT_MODES:
            cfg = AdaptationConfig(
                target_coverage=target, gamma=gamma, window=512, every=32,
                refresh_every=makespan_est / 8.0 if refresh else 0.0,
                refresh_min_samples=512, refresh_epochs=2,
                buffer_size=4096, refresh_seed=seed + 11)
            adapter = OnlineAdapter(PredictorService(head, window=16.0), cfg)
            cl = Cluster.uniform(n_replicas, max_slots, kv_budget, pol,
                                 router="psq", predictor=adapter,
                                 admission=AdmissionController())
            t0 = time.time()
            st = cl.run(reqs)
            dt = time.time() - t0
            cov, cov_post = _coverage_split(cl, switch)
            row = st.row()
            row.update(trace=tname, mode=label, coverage=cov,
                       coverage_post=cov_post, seconds=dt,
                       adapter=adapter.row(),
                       service=adapter.base.stats.row())
            rows.append(row)
            if verbose:
                print(f"  {tname:11s} {label:18s} {cov:6.3f} {cov_post:7.3f} "
                      f"{st.p99_latency:9.1f} {st.slo_violations:6d} "
                      f"{st.timed_out:6d} {st.rejected:6d} "
                      f"{st.refreshes:5d} {adapter.q_eff:6.3f} "
                      f"{st.goodput:8.2f} {dt:6.1f}")
    return rows


def validate_cluster_adaptation(rows, target=0.9) -> dict:
    if not rows:
        return {"empty_trace": True}
    by = {(r["trace"], r["mode"]): r for r in rows}
    stat_static = by[("stationary", "static")]
    stat_adapt = by[("stationary", "conformal+refresh")]
    dr_static = by[("drift", "static")]
    dr_conf = by[("drift", "conformal")]
    dr_adapt = by[("drift", "conformal+refresh")]
    return {
        # acceptance: static coverage collapses under drift ...
        "static_drift_cov_drop": target - dr_static["coverage_post"],
        "static_drift_degrades": dr_static["coverage_post"] <= target - 0.10,
        # ... while the adapted stack holds the target post-switch
        "adapted_drift_cov_err": abs(dr_adapt["coverage_post"] - target),
        "adapted_holds_target": abs(dr_adapt["coverage_post"] - target)
        <= 0.05,
        "conformal_recovers": dr_conf["coverage_post"]
        > dr_static["coverage_post"],
        "refresh_used": dr_adapt["refreshes"] > 0,
        "refresh_cuts_slo_misses": (dr_adapt["slo_violations"]
                                    + dr_adapt["timed_out"])
        <= (dr_static["slo_violations"] + dr_static["timed_out"]),
        # no p99 regression from running the adaptation loop when stationary
        "stationary_p99_ok": stat_adapt["p99_latency"]
        <= 1.05 * stat_static["p99_latency"],
        "stationary_cov_err": abs(stat_adapt["coverage"] - target),
        "replay_under_120s": all(r["seconds"] < 120.0 for r in rows),
    }


# ---------------------------------------------------------------------------
# observability smoke: tracer inertness, path equality, artifact export
# ---------------------------------------------------------------------------


def run_obs(n_requests=8000, n_replicas=4, max_slots=32, pattern="bursty",
            seed=0, out_dir=".", verbose=True):
    """Telemetry smoke table: replay one traced cluster on both decode paths
    plus an untraced control, then export the Perfetto/Prometheus/JSON
    artifacts from the vectorized trace. The three runs pin the telemetry
    contract end to end — tracing must not perturb the simulation
    (control == traced rows), the reference and event-leap paths must emit
    the same canonical event stream, and the event log must conserve
    requests (every arrival reaches exactly one terminal event)."""
    n_requests = min(int(n_requests), 8000)   # the ref path steps every tick
    probe = make_trace(TraceConfig(n_requests=2000, rate=1.0, seed=seed))
    rate = stable_rate(n_replicas, max_slots, mean_true_length(probe), 0.7)
    cfg = TraceConfig(n_requests=n_requests, rate=rate, pattern=pattern,
                      model="mix", scenario="mix", seed=seed,
                      slo_factor=3.0, slo_floor=80.0)
    reqs = make_trace(cfg)
    if not reqs:
        print("empty trace (n_requests=0): nothing to replay")
        return []
    kv_budget = 8 * (256 + 4096)
    oracle = make_oracle(cfg)
    pol = Policy("srtf_pred", "quantile", quantile=0.9, preempt=True,
                 preempt_factor=1.5, preempt_mode="keep")
    rows, tracers = [], {}
    for label, vec, tracer in (("control", True, None),
                               ("vec", True, Tracer(sample_every=32)),
                               ("ref", False, Tracer(sample_every=32))):
        t0 = time.time()
        cl = Cluster.uniform(n_replicas, max_slots, kv_budget, pol,
                             router="psq", predictor=oracle,
                             rebalance_every=64, steal="quantile",
                             admission=AdmissionController(slack=0.9,
                                                           tracer=tracer),
                             vectorized=vec, tracer=tracer)
        st = cl.run(reqs)
        dt = time.time() - t0
        row = st.row()
        row.update(path=label, seconds=dt,
                   events=tracer.emitted if tracer else 0,
                   samples=len(tracer.series) if tracer else 0)
        rows.append(row)
        if tracer is not None:
            tracers[label] = tracer
        if verbose:
            print(f"  {label:8s} p99 {st.p99_latency:9.1f} "
                  f"goodput {st.goodput:8.2f} events {row['events']:7d} "
                  f"samples {row['samples']:5d} {dt:6.1f}s")
    tr = tracers["vec"]
    # cross-run facts the validator needs but a single row can't see
    rows[1]["_events_equal"] = (tr.canonical()
                                == tracers["ref"].canonical())
    rows[1]["_terminal"] = dict(tr.terminal_counts())
    os.makedirs(out_dir, exist_ok=True)
    tr.write_perfetto(os.path.join(out_dir, "trace.json"))
    with open(os.path.join(out_dir, "metrics.prom"), "w") as f:
        f.write(tr.to_prometheus())
    tr.write_summary(os.path.join(out_dir, "summary.json"))
    if verbose:
        print(f"  artifacts -> {out_dir}/{{trace.json,metrics.prom,"
              f"summary.json}}")
    return rows


def validate_obs(rows, n_requests=8000) -> dict:
    if not rows:
        return {"empty_trace": True}
    n_requests = min(int(n_requests), 8000)
    by = {r["path"]: r for r in rows}

    def core(r):
        return {k: v for k, v in r.items() if not k.startswith("_")
                and k not in ("path", "seconds", "events", "samples")}

    term = by["vec"].get("_terminal", {})
    accounted = (term.get("finish", -1) == by["vec"]["completed"]
                 and term.get("timeout", -1) == by["vec"]["timed_out"]
                 and term.get("rejected", -1) == by["vec"]["rejected"]
                 and sum(term.values()) == n_requests)
    return {
        "tracer_off_inert": core(by["control"]) == core(by["vec"]),
        "paths_bitexact_rows": core(by["vec"]) == core(by["ref"]),
        "paths_bitexact_events": by["vec"].get("_events_equal", False),
        "all_accounted": accounted,
        "events_emitted": by["vec"]["events"] > 0,
        "series_sampled": by["vec"]["samples"] > 0,
        "replay_under_120s": all(r["seconds"] < 120.0 for r in rows),
    }


def _git_sha():
    """Best-effort current commit SHA for stamp provenance ("unknown" when
    git is unavailable — e.g. a source tarball)."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _write_stamp(path, tables, timestamp=None, **meta):
    """Stamp bench rows + validation checks to ``path`` (JSON). The file is
    the start of the serving perf trajectory: each entry is one table's raw
    rows and its ``validate_*`` booleans/metrics, keyed by table name, plus
    a ``meta`` block (config knobs, git SHA, caller-supplied timestamp)
    recording the provenance ``check_regression.py`` keys its diff on.
    Tables already stamped in an existing file are preserved, and meta is
    merged non-destructively (existing keys survive unless this run supplies
    a new value — a ``--X-only`` refresh must not erase the provenance of
    the tables it did not rerun), so a partial run refreshes one table
    without dropping the rest of the trajectory."""
    import json

    def scrub(x):
        if isinstance(x, dict):
            return {k: scrub(v) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return [scrub(v) for v in x]
        if isinstance(x, (np.integer,)):
            return int(x)
        if isinstance(x, (np.floating,)):
            return float(x)
        if isinstance(x, (np.bool_,)):
            return bool(x)
        return x

    merged, old_meta = {}, {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prior = json.load(f)
            merged = prior.get("tables", {})
            old_meta = prior.get("meta", {})
        except (ValueError, OSError):
            merged, old_meta = {}, {}
    merged.update(scrub(tables))
    new_meta = dict(old_meta)
    new_meta.update(scrub(meta))
    new_meta["git_sha"] = _git_sha()
    if timestamp is not None:
        # caller-supplied (wall-clock stays out of the bench library so runs
        # stay replayable); an unstamped refresh keeps the previous one
        new_meta["timestamp"] = str(timestamp)
    with open(path, "w") as f:
        json.dump({"meta": new_meta, "tables": merged}, f, indent=1,
                  sort_keys=True)
    print(f"stamped {len(tables)} table(s) ({len(merged)} total) -> {path}")


def main(fast=True, cluster=True, cluster_only=False, adaptation_only=False,
         preemption_only=False, prefix_only=False, chunked_only=False,
         refine_only=False, obs_only=False, n_requests=50_000, n_replicas=4,
         max_slots=32, pattern="bursty", seed=0, hetero=True, predictors=True,
         adaptation=True, preemption=True, prefix=True, chunked=True,
         refine=True, stamp=None, timestamp=None, obs_dir="obs_artifacts"):
    tables = {}

    def finish(name, rows, checks):
        tables[name] = {"rows": rows, "checks": checks}
        if stamp:
            _write_stamp(stamp, tables, timestamp=timestamp,
                         n_requests=n_requests,
                         n_replicas=n_replicas, max_slots=max_slots,
                         pattern=pattern, seed=seed)

    if obs_only:
        orows = run_obs(n_requests=n_requests, n_replicas=n_replicas,
                        max_slots=max_slots, pattern=pattern, seed=seed,
                        out_dir=obs_dir)
        checks = validate_obs(orows, n_requests=n_requests)
        print("obs checks:", checks)
        finish("obs", orows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so a telemetry perturbation (tracer-on divergence,
        # path-dependent event streams, or a leaky event log) turns the
        # nightly job red
        hard = ("tracer_off_inert", "paths_bitexact_rows",
                "paths_bitexact_events", "all_accounted", "events_emitted",
                "series_sampled", "replay_under_120s")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"obs acceptance failed: {bad}")
        return orows
    if refine_only:
        rrows = run_cluster_refine(n_requests=n_requests,
                                   n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_refine(rrows)
        print("refine checks:", checks)
        finish("cluster_refine", rrows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so a posterior-refinement regression (tail latency, SLO,
        # calibration-vs-progress, or hazard capacity hand-back) turns the
        # nightly job red
        hard = ("all_accounted", "refine_exercised", "prompt_only_is_inert",
                "mae_posterior_wins_at_some_t",
                "posterior_srtf_p99_not_worse", "posterior_srtf_slo_win",
                "hazard_laxity_goodput_not_worse", "replay_under_120s")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"refine acceptance failed: {bad}")
        return rrows
    if chunked_only:
        crows = run_cluster_chunked(n_requests=n_requests,
                                    n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_chunked(crows)
        print("chunked checks:", checks)
        finish("cluster_chunked", crows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so a chunked-prefill/TTFT regression turns the nightly
        # job red
        hard = ("all_accounted", "prod_chunked_beats_fcfs_atomic_p99_ttft",
                "prod_beats_fcfs_chunked_mean_ttft", "throughput_equal",
                "chunk_order_only_throughput_ok",
                "chunking_throughput_not_worse", "replay_under_90s")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"chunked acceptance failed: {bad}")
        return crows
    if prefix_only:
        prows = run_cluster_prefix(n_requests=n_requests,
                                   n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_prefix(prows)
        print("prefix checks:", checks)
        finish("cluster_prefix", prows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so a prefix-sharing/affinity regression turns the
        # nightly job red
        hard = ("all_completed", "off_is_inert", "amplification_over_1_2",
                "prefill_reduced", "affine_beats_jsq_amp",
                "affine_beats_jsq_saved", "affine_p99_not_worse")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"prefix acceptance failed: {bad}")
        return prows
    if preemption_only:
        prows = run_cluster_preemption(n_requests=n_requests,
                                       n_replicas=n_replicas,
                                       max_slots=max_slots, pattern=pattern,
                                       seed=seed)
        checks = validate_cluster_preemption(prows)
        print("preemption checks:", checks)
        finish("cluster_preemption", prows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so a keep-pages regression turns the nightly job red
        hard = ("preemptions_exercised", "keep_cuts_recompute_ticks",
                "keep_p99_reduced_srtf", "keep_p99_within_5pct_everywhere",
                "keep_holds_pages", "no_regression_when_preempt_off",
                "all_accounted", "load_feasible")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"preemption acceptance failed: {bad}")
        return prows
    if adaptation_only:
        arows = run_cluster_adaptation(n_requests=n_requests,
                                       n_replicas=n_replicas,
                                       max_slots=max_slots, pattern=pattern,
                                       seed=seed)
        checks = validate_cluster_adaptation(arows)
        print("adaptation checks:", checks)
        finish("cluster_adaptation", arows, checks)
        # CI smoke mode is a regression gate: hard-fail on the acceptance
        # booleans so nightly drift/coverage breakage turns the job red
        hard = ("static_drift_degrades", "adapted_holds_target",
                "conformal_recovers", "refresh_used", "stationary_p99_ok")
        bad = [k for k in hard if not checks.get(k, False)]
        if bad:
            raise SystemExit(f"adaptation acceptance failed: {bad}")
        return arows
    rows = None
    if not cluster_only:
        rows = run(fast=fast)
        checks = validate(rows)
        print("checks:", checks)
        finish("single_replica", rows, checks)
    if cluster or cluster_only:
        crows = run_cluster(n_requests=n_requests, n_replicas=n_replicas,
                            max_slots=max_slots, pattern=pattern, seed=seed)
        checks = validate_cluster(crows)
        print("cluster checks:", checks)
        finish("cluster", crows, checks)
    if hetero and (cluster or cluster_only):
        hrows = run_cluster_hetero(n_requests=n_requests, max_slots=max_slots,
                                   pattern=pattern, seed=seed)
        checks = validate_cluster_hetero(hrows)
        print("hetero checks:", checks)
        finish("cluster_hetero", hrows, checks)
    if predictors and (cluster or cluster_only):
        prows = run_cluster_predictors(n_requests=n_requests,
                                       n_replicas=n_replicas,
                                       max_slots=max_slots, pattern=pattern,
                                       seed=seed)
        checks = validate_cluster_predictors(prows)
        print("predictor checks:", checks)
        finish("cluster_predictors", prows, checks)
    if preemption and (cluster or cluster_only):
        prows = run_cluster_preemption(n_requests=n_requests,
                                       n_replicas=n_replicas,
                                       max_slots=max_slots, pattern=pattern,
                                       seed=seed)
        checks = validate_cluster_preemption(prows)
        print("preemption checks:", checks)
        finish("cluster_preemption", prows, checks)
    if adaptation and (cluster or cluster_only):
        arows = run_cluster_adaptation(n_requests=n_requests,
                                       n_replicas=n_replicas,
                                       max_slots=max_slots, pattern=pattern,
                                       seed=seed)
        checks = validate_cluster_adaptation(arows)
        print("adaptation checks:", checks)
        finish("cluster_adaptation", arows, checks)
    if prefix and (cluster or cluster_only):
        frows = run_cluster_prefix(n_requests=n_requests,
                                   n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_prefix(frows)
        print("prefix checks:", checks)
        finish("cluster_prefix", frows, checks)
    if chunked and (cluster or cluster_only):
        krows = run_cluster_chunked(n_requests=n_requests,
                                    n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_chunked(krows)
        print("chunked checks:", checks)
        finish("cluster_chunked", krows, checks)
    if refine and (cluster or cluster_only):
        rrows = run_cluster_refine(n_requests=n_requests,
                                   n_replicas=n_replicas, seed=seed)
        checks = validate_cluster_refine(rrows)
        print("refine checks:", checks)
        finish("cluster_refine", rrows, checks)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster-only", action="store_true")
    ap.add_argument("--adaptation-only", action="store_true",
                    help="run only the online-adaptation table (CI smoke)")
    ap.add_argument("--preemption-only", action="store_true",
                    help="run only the recompute-vs-keep preemption table "
                         "(CI smoke)")
    ap.add_argument("--prefix-only", action="store_true",
                    help="run only the prefix-sharing/affinity table "
                         "(CI smoke)")
    ap.add_argument("--chunked-only", action="store_true",
                    help="run only the chunked-prefill TTFT-vs-throughput "
                         "table (CI smoke)")
    ap.add_argument("--refine-only", action="store_true",
                    help="run only the mid-flight posterior-refinement "
                         "table (CI smoke)")
    ap.add_argument("--obs-only", action="store_true",
                    help="run only the telemetry smoke table (CI smoke): "
                         "tracer inertness + path equality + conservation, "
                         "and export Perfetto/Prometheus/JSON artifacts")
    ap.add_argument("--obs-dir", metavar="DIR", default="obs_artifacts",
                    help="directory for --obs-only artifacts "
                         "(trace.json, metrics.prom, summary.json)")
    ap.add_argument("--stamp", metavar="PATH", default=None,
                    help="write rows + validation checks of every table run "
                         "to PATH as JSON (e.g. BENCH_serving.json)")
    ap.add_argument("--timestamp", default=None,
                    help="provenance timestamp recorded in the stamp's meta "
                         "block (caller-supplied, e.g. $(date -uIs))")
    ap.add_argument("--no-hetero", action="store_true",
                    help="skip the heterogeneous x SLO x stealing table")
    ap.add_argument("--no-predictors", action="store_true",
                    help="skip the trained-head vs oracles x ordering table")
    ap.add_argument("--no-adaptation", action="store_true",
                    help="skip the online-adaptation (drift/conformal) table")
    ap.add_argument("--no-preemption", action="store_true",
                    help="skip the recompute-vs-keep preemption table")
    ap.add_argument("--no-prefix", action="store_true",
                    help="skip the prefix-sharing/affinity table")
    ap.add_argument("--no-chunked", action="store_true",
                    help="skip the chunked-prefill TTFT table")
    ap.add_argument("--no-refine", action="store_true",
                    help="skip the posterior-refinement table")
    ap.add_argument("--n-requests", type=int, default=50_000)
    ap.add_argument("--n-replicas", type=int, default=4)
    ap.add_argument("--max-slots", type=int, default=32)
    ap.add_argument("--pattern", default="bursty",
                    choices=("poisson", "bursty", "diurnal"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(cluster_only=args.cluster_only, adaptation_only=args.adaptation_only,
         preemption_only=args.preemption_only, prefix_only=args.prefix_only,
         chunked_only=args.chunked_only, refine_only=args.refine_only,
         obs_only=args.obs_only, n_requests=args.n_requests,
         n_replicas=args.n_replicas, max_slots=args.max_slots,
         pattern=args.pattern, seed=args.seed, hetero=not args.no_hetero,
         predictors=not args.no_predictors,
         adaptation=not args.no_adaptation,
         preemption=not args.no_preemption, prefix=not args.no_prefix,
         chunked=not args.no_chunked, refine=not args.no_refine,
         stamp=args.stamp, timestamp=args.timestamp, obs_dir=args.obs_dir)
