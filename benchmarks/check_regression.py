"""Perf-trajectory regression gate over ``BENCH_serving.json`` stamps.

``bench_serving.py --stamp`` records each table's raw rows, validation
checks, and a provenance ``meta`` block (config knobs, git SHA, timestamp).
This script diffs a freshly produced candidate stamp against the committed
baseline and fails (exit 1) when any row regresses beyond the configured
tolerances:

* tail latency — ``p99_latency`` / ``p99_ttft`` may grow at most
  ``--max-p99-regress`` (fractional, default 10%);
* ``goodput`` may shrink to no less than ``--min-goodput-ratio`` of the
  baseline (default 95%).

Comparison rules keep the diff honest rather than exhaustive:

* only tables present in BOTH stamps are compared — the trajectory grows a
  table at a time, and a new table has no baseline yet;
* rows are matched positionally within a table and must agree on their
  identity fields (router/policy/mode/...): an identity mismatch means the
  bench matrix itself changed, so the row pair is reported as *skipped*,
  not scored — a matrix change needs a baseline refresh, not a red gate;
* the meta config knobs (n_requests, replicas, slots, pattern, seed) must
  match, else the candidate measured a different experiment and every
  per-row delta is noise (``--ignore-meta`` overrides, for local spelunking).

Typical CI usage::

    python benchmarks/bench_serving.py --cluster-only --n-requests 8000 \
        --stamp /tmp/candidate.json
    python benchmarks/check_regression.py \
        --baseline BENCH_serving.json --candidate /tmp/candidate.json
"""

from __future__ import annotations

import argparse
import json
import sys

# meta knobs that define "same experiment"; git_sha/timestamp are provenance,
# not identity
META_KNOBS = ("n_requests", "n_replicas", "max_slots", "pattern", "seed")

# row fields that identify which configuration a row measured (present
# subsets vary by table)
ID_FIELDS = ("router", "policy", "mode", "trace", "chunk", "chunk_order",
             "balance_mode", "path", "predictor", "label", "order", "steal")

# (metric, direction): +1 means larger-is-worse (latency), -1 smaller-is-worse
P99_METRICS = ("p99_latency", "p99_ttft")
GOODPUT_METRIC = "goodput"


def load_stamp(path):
    with open(path) as f:
        doc = json.load(f)
    if "tables" not in doc:
        raise SystemExit(f"{path}: not a bench stamp (no 'tables' key)")
    return doc


def row_identity(row):
    return {k: row[k] for k in ID_FIELDS if k in row}


def compare(baseline, candidate, max_p99_regress, min_goodput_ratio,
            ignore_meta=False):
    """Return (violations, skipped, compared) lists of human-readable
    strings; the gate is red iff ``violations`` is non-empty."""
    violations, skipped, compared = [], [], []
    if not ignore_meta:
        bm, cm = baseline.get("meta", {}), candidate.get("meta", {})
        for k in META_KNOBS:
            if k in bm and k in cm and bm[k] != cm[k]:
                violations.append(
                    f"meta mismatch: {k} baseline={bm[k]!r} "
                    f"candidate={cm[k]!r} (different experiment; rerun with "
                    f"matching knobs or pass --ignore-meta)")
        if violations:
            return violations, skipped, compared
    bt, ct = baseline["tables"], candidate["tables"]
    for name in sorted(set(bt) & set(ct)):
        brows = bt[name].get("rows", [])
        crows = ct[name].get("rows", [])
        if len(brows) != len(crows):
            skipped.append(f"{name}: row count {len(brows)} -> {len(crows)} "
                           f"(matrix changed; refresh the baseline)")
            continue
        for i, (b, c) in enumerate(zip(brows, crows)):
            bid, cid = row_identity(b), row_identity(c)
            tag = f"{name}[{i}]" + (f" {bid}" if bid else "")
            if bid != cid:
                skipped.append(f"{tag}: identity changed to {cid} "
                               f"(matrix changed; refresh the baseline)")
                continue
            for m in P99_METRICS:
                if m not in b or m not in c:
                    continue
                base, cand = float(b[m]), float(c[m])
                limit = base * (1.0 + max_p99_regress)
                compared.append(f"{tag}.{m}: {base:.2f} -> {cand:.2f}")
                if cand > limit:
                    violations.append(
                        f"{tag}.{m}: {base:.2f} -> {cand:.2f} "
                        f"(+{(cand / max(base, 1e-12) - 1) * 100:.1f}%, "
                        f"limit +{max_p99_regress * 100:.0f}%)")
            if GOODPUT_METRIC in b and GOODPUT_METRIC in c:
                base = float(b[GOODPUT_METRIC])
                cand = float(c[GOODPUT_METRIC])
                compared.append(
                    f"{tag}.{GOODPUT_METRIC}: {base:.2f} -> {cand:.2f}")
                if cand < base * min_goodput_ratio:
                    violations.append(
                        f"{tag}.{GOODPUT_METRIC}: {base:.2f} -> {cand:.2f} "
                        f"({cand / max(base, 1e-12) * 100:.1f}% of baseline, "
                        f"floor {min_goodput_ratio * 100:.0f}%)")
    if not compared and not skipped:
        violations.append("no comparable tables between baseline and "
                          "candidate (nothing was gated)")
    return violations, skipped, compared


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fail when a fresh bench stamp regresses vs the "
                    "committed one")
    ap.add_argument("--baseline", required=True,
                    help="committed stamp (e.g. BENCH_serving.json)")
    ap.add_argument("--candidate", required=True,
                    help="freshly produced stamp to gate")
    ap.add_argument("--max-p99-regress", type=float, default=0.10,
                    help="max fractional p99 latency/TTFT growth "
                         "(default 0.10 = +10%%)")
    ap.add_argument("--min-goodput-ratio", type=float, default=0.95,
                    help="min candidate/baseline goodput ratio "
                         "(default 0.95)")
    ap.add_argument("--ignore-meta", action="store_true",
                    help="compare rows even when the meta config knobs "
                         "differ")
    ap.add_argument("--verbose", action="store_true",
                    help="print every compared metric, not just violations")
    args = ap.parse_args(argv)
    baseline = load_stamp(args.baseline)
    candidate = load_stamp(args.candidate)
    violations, skipped, compared = compare(
        baseline, candidate, args.max_p99_regress, args.min_goodput_ratio,
        ignore_meta=args.ignore_meta)
    if args.verbose:
        for line in compared:
            print("  ok  " + line)
    for line in skipped:
        print("skip  " + line)
    print(f"{len(compared)} metric(s) compared, {len(skipped)} skipped, "
          f"{len(violations)} violation(s)")
    if violations:
        for line in violations:
            print("FAIL  " + line, file=sys.stderr)
        return 1
    print("no perf regression vs baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
