"""Figure 2 — budget fairness: MAE vs repeat number under a fixed inference
budget. With repeat number k only ceil(B/k) unique prompts are kept; compares
ProD-M / ProD-D against the full-coverage single-sample TRAIL-last baseline.
Validates: repeated sampling pays off at fixed budget on the hard scenarios.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scenario_pcfg
from repro.core import bins as B
from repro.core import targets as T
from repro.core.metrics import mae
from repro.core.predictor import train_predictor
from repro.data import make_scenario

REPEATS = (1, 2, 4, 8, 16)


def _fit(key, phi, lens, kind, decode, pcfg):
    edges = B.make_edges(pcfg.n_bins, pcfg.bin_max)
    tgt = T.build_target(jnp.asarray(lens, jnp.float32), edges, kind)
    p = train_predictor(key, jnp.asarray(phi), tgt, pcfg, edges)
    return p, decode


def run(scenarios=(("qwen", "math"), ("qwen", "longseq"), ("qwen", "chat"),
                   ("llama", "longseq")),
        fast=True, seed=0, n_trials=2, verbose=True):
    out = {}
    for model, scen in scenarios:
        data = make_scenario(model, scen, n_train=800 if fast else None,
                             n_test=400 if fast else None, seed=seed,
                             full_paper_splits=not fast)
        pcfg = scenario_pcfg(data, epochs=15 if fast else 30)
        Bn = data.len_train.shape[0]
        y_test = T.sample_median(jnp.asarray(data.len_test, jnp.float32))
        phi_te = jnp.asarray(data.phi_test["last"])
        curves = {}
        for k in REPEATS:
            n_keep = int(np.ceil(Bn / k))
            for method, kind, decode in (("prod_m", "median", "median"),
                                         ("prod_d", "dist", "median")):
                maes = []
                for t in range(n_trials):
                    rng = np.random.default_rng(seed * 77 + t)
                    idx = rng.permutation(Bn)[:n_keep]
                    lens_k = data.len_train[idx][:, :k]
                    if k == 1 and method == "prod_d":
                        continue  # degenerate
                    p, dec = _fit(jax.random.PRNGKey(t), data.phi_train["last"][idx],
                                  lens_k, kind if k > 1 else "single", decode, pcfg)
                    maes.append(mae(p.predict(phi_te, dec), y_test))
                if maes:
                    curves.setdefault(method, {})[k] = (
                        float(np.mean(maes)), float(np.std(maes)))
        # full-coverage single-sample TRAIL-last baseline
        maes = []
        for t in range(n_trials):
            p, dec = _fit(jax.random.PRNGKey(100 + t), data.phi_train["last"],
                          data.len_train[:, t: t + 1], "single", "mean", pcfg)
            maes.append(mae(p.predict(phi_te, dec), y_test))
        curves["trail_last_full"] = {1: (float(np.mean(maes)), float(np.std(maes)))}
        out[(model, scen)] = curves
        if verbose:
            best_k = min(curves["prod_d"], key=lambda k: curves["prod_d"][k][0])
            print(f"  [{model}/{scen}] trail_full={curves['trail_last_full'][1][0]:.1f} "
                  f"prod_d best k={best_k} ({curves['prod_d'][best_k][0]:.1f})")
    return out


def validate(out) -> dict:
    checks = {}
    for (model, scen), curves in out.items():
        base = curves["trail_last_full"][1][0]
        best = min(v[0] for v in curves["prod_d"].values())
        checks[f"{model}/{scen}_repeat_beats_full_coverage"] = bool(best <= base * 1.02)
    return checks


def main(fast=True):
    out = run(fast=fast)
    print("checks:", validate(out))
    return out


if __name__ == "__main__":
    main()
