"""Roofline report (deliverable g): aggregates results/dryrun/*.json into the
per-(arch × shape) three-term table with dominant bottleneck, MODEL_FLOPS
ratio, and a one-line "what would move the dominant term" note."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

NOTES = {
    ("compute_s", "train"): "more chips / lower-precision matmuls / drop remat recompute",
    ("compute_s", "prefill"): "causal block-skip halves masked-out attention FLOPs",
    ("compute_s", "decode"): "batch more requests per step",
    ("memory_s", "train"): "fuse optimizer update; shard activations over seq",
    ("memory_s", "prefill"): "keep KV in bf16; larger flash tiles",
    ("memory_s", "decode"): "quantize KV cache (int8) halves the dominant cache reads",
    ("collective_s", "train"): "overlap TP all-reduces with compute; reduce-scatter + all-gather (seq-parallel)",
    ("collective_s", "prefill"): "same as train; shard seq dim for norm regions",
    ("collective_s", "decode"): "all-to-all token dispatch instead of expert-weight gathering (MoE) / TP-only weights",
}


def load(results_dir="results/dryrun", mesh="pod", variant=None) -> List[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, f"*_{mesh}*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh:
            continue
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        rows.append(r)
    return rows


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(shape, "decode")


def table(rows: List[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'var':9s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>12s} {'useful':>7s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} FAILED: {r.get('error','')[:60]}")
            continue
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r.get('variant','base')[:9]:9s} "
            f"{rf['compute_s']:10.3e} {rf['memory_s']:10.3e} "
            f"{rf['collective_s']:10.3e} {rf['dominant']:>12s} "
            f"{rf['useful_flops_ratio']:7.3f} {str(r.get('hbm_ok'))[:5]:>5s}")
    return "\n".join(lines)


def notes(rows: List[dict]) -> List[str]:
    out = []
    for r in rows:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        key = (rf["dominant"], kind_of(r["shape"]))
        out.append(f"{r['arch']} × {r['shape']}: {rf['dominant'].replace('_s','')}"
                   f"-bound — {NOTES.get(key, 'see §Perf')}")
    return out


def main():
    rows = load()
    print(table(rows))
    ok = [r for r in rows if r.get("ok")]
    print(f"\n{len(ok)}/{len(rows)} combinations lower+compile on the 16x16 pod mesh")
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["useful_flops_ratio"]
                    if r["shape"] == "train_4k" else 1e9)
        print(f"worst train useful-FLOPs ratio: {worst['arch']} "
              f"({worst['roofline']['useful_flops_ratio']:.3f})")
    return rows


if __name__ == "__main__":
    main()
