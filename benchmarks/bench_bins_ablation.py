"""Beyond-paper ablation: bin-grid spacing under heavy tails.

The paper uses linear bins. Heavy-tailed length laws suggest log-spaced bins
(constant RELATIVE resolution), especially on chat where the cross-prompt
median spans two orders of magnitude. Also sweeps K to show robustness of the
ProD-D pipeline to the grid.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scenario_pcfg
from repro.core import bins as B
from repro.core import targets as T
from repro.core.metrics import mae
from repro.core.predictor import train_predictor
from repro.data import make_scenario


def run(scenarios=(("qwen", "chat"), ("qwen", "math")), fast=True, seed=0,
        verbose=True):
    out = {}
    for model, scen in scenarios:
        data = make_scenario(model, scen, n_train=800 if fast else None,
                             n_test=400 if fast else None, seed=seed,
                             full_paper_splits=not fast)
        y_test = T.sample_median(jnp.asarray(data.len_test, jnp.float32))
        phi_tr = jnp.asarray(data.phi_train["last"])
        phi_te = jnp.asarray(data.phi_test["last"])
        res = {}
        for spacing in ("linear", "log"):
            for K in (16, 64, 128):
                pcfg = dataclasses.replace(
                    scenario_pcfg(data, n_bins=K, epochs=15 if fast else 30),
                    bin_spacing=spacing)
                edges = B.make_edges(K, pcfg.bin_max, spacing)
                tgt = T.dist_target(jnp.asarray(data.len_train, jnp.float32),
                                    edges)
                p = train_predictor(jax.random.PRNGKey(seed), phi_tr, tgt,
                                    pcfg, edges)
                res[(spacing, K)] = mae(p.predict(phi_te), y_test)
        out[(model, scen)] = res
        if verbose:
            for k, v in sorted(res.items()):
                print(f"  [{model}/{scen}] {k[0]:6s} K={k[1]:3d}  MAE {v:7.2f}")
    return out


def validate(out) -> dict:
    checks = {}
    for (model, scen), res in out.items():
        lin = min(v for (sp, _), v in res.items() if sp == "linear")
        log = min(v for (sp, _), v in res.items() if sp == "log")
        checks[f"{model}/{scen}_log_vs_linear_pct"] = round(
            100 * (lin - log) / lin, 1)
        # the insight: LOG grids stay robust across K even on heavy-tailed
        # scenarios, while coarse LINEAR grids can blow up (chat, K=16)
        logs = [v for (sp, _), v in res.items() if sp == "log"]
        checks[f"{model}/{scen}_log_grid_robust"] = bool(
            max(logs) < 1.25 * min(logs))
    return checks


def main(fast=True):
    out = run(fast=fast)
    print("checks:", validate(out))
    return out


if __name__ == "__main__":
    main()
