"""Tables 2–3 — single-sample supervision ablation.

Every predictor family retrained with ONE sampled length per prompt;
evaluated against (T2) the single-label target and (T3) the 16-sample median
target, mean ± std over trials. ProD-D is omitted (degenerate under a single
sample — paper §3.3). Validates: single-sample supervision degrades every
method vs Table 1, and ProD-M stays best.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import all_settings, scenario_pcfg
from repro.core.baselines import run_method

ABLATION_METHODS = ("s3", "trail_mean", "trail_last", "egtp", "prod_m")


def run(fast=True, seed=0, n_trials=3, verbose=True):
    out = {"single": {}, "median": {}}
    for model, scen, data, epochs in all_settings(fast=fast, seed=seed):
        pcfg = scenario_pcfg(data, epochs=epochs)
        for method in ABLATION_METHODS:
            for ev in ("single", "median"):
                maes = []
                for t in range(n_trials):
                    import zlib
                    key = jax.random.PRNGKey(1000 * t + zlib.crc32(method.encode()) % 997)
                    res = run_method(key, data, method, pcfg,
                                     supervision="single", single_idx=t,
                                     eval_target=ev)
                    maes.append(res.test_mae)
                out[ev].setdefault(method, {})[(model, scen)] = (
                    float(np.mean(maes)), float(np.std(maes)))
        if verbose:
            m, s = out["median"]["prod_m"][(model, scen)]
            print(f"  [{model}/{scen}] prod_m(single-sup, median-eval) = {m:.1f}±{s:.1f}")
    return out


def validate(t23, t1_rows) -> dict:
    settings = list(t23["median"]["prod_m"].keys())
    avg23 = lambda m: float(np.mean([t23["median"][m][s][0] for s in settings]))
    # per-setting RELATIVE degradation (a flat average is dominated by chat,
    # where both regimes are feature-noise-bound and supervision noise is
    # immaterial — consistent with the paper's pattern of smaller relative
    # gaps on chat)
    rel = [
        (t23["median"]["prod_m"][s][0] - t1_rows["prod_m"][s])
        / max(t1_rows["prod_m"][s], 1e-9) for s in settings
    ]
    avg_single_eval = float(np.mean(
        [t23["single"]["prod_m"][s][0] for s in settings]))
    avg_median_eval = avg23("prod_m")
    return {
        "prod_m_best_in_ablation": avg23("prod_m") <= min(
            avg23(m) for m in ABLATION_METHODS),
        # paper's T2 > T3 pattern: the one-shot test target injects its own
        # noise on top of the predictor error
        "single_eval_noisier_than_median_eval":
            avg_single_eval > avg_median_eval,
        "mean_relative_degradation_pct": float(100 * np.mean(rel)),
        "max_relative_degradation_pct": float(100 * np.max(rel)),
    }


def main(fast=True):
    out = run(fast=fast)
    print("\nTable 2/3 averages (single-sample supervision):")
    for ev in ("single", "median"):
        for method in ABLATION_METHODS:
            vals = [v[0] for v in out[ev][method].values()]
            print(f"  eval={ev:7s} {method:12s} {np.mean(vals):8.2f}")
    return out


if __name__ == "__main__":
    main()
