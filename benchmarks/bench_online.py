"""ProD-O (beyond paper): online remaining-length prediction on real
generations — the paper's §5 roadmap, built from its §2.2 general formulation.

Pipeline: train tiny LM → generate with per-step hidden-state collection →
train the remaining-length head → compare against the static prompt-only
baseline max(L̂ − t, 0), bucketed by decode progress.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PredictorConfig, TrainConfig
from repro.configs import get_config
from repro.core import bins as B
from repro.core import online
from repro.core import targets as T
from repro.core.predictor import train_predictor
from repro.data.pipeline import batch_iterator, make_lm_dataset
from repro.data.tokenizer import N_TOPICS, ToyTokenizer
from repro.models.model_zoo import Runtime, build_model
from repro.serving.engine import RealEngine
from repro.training.trainer import train_loop


def run(train_steps=150, n_prompts=48, max_new=80, seed=0, verbose=True):
    cfg = get_config("tiny-lm").with_overrides(dtype="float32")
    model = build_model(cfg)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, decay_steps=train_steps,
                       seed=seed)
    ds = make_lm_dataset(1024, 96, seed=seed)
    state = train_loop(model, tcfg, batch_iterator(ds, 16, seed=seed),
                       train_steps, rt=Runtime.local(), verbose=False)
    eng = RealEngine(model, state.params, max_new=max_new, temperature=0.8)
    rng = np.random.default_rng(seed)
    tok = ToyTokenizer()
    prompts = np.zeros((n_prompts, 6), np.int32)
    for i in range(n_prompts):
        prompts[i] = tok.prompt(rng, int(rng.integers(0, N_TOPICS)), n_style=4)
    plens = np.full(n_prompts, 6)
    out = eng.generate(prompts, plens, jax.random.PRNGKey(seed),
                       collect_per_step=True)
    lens = out["lengths"]
    phi0 = out["phi"]

    # static prompt-only predictor (ProD-D on a second repeated-sampling pass)
    lens_rep, _ = eng.repeated_sampling(prompts, plens, r=4, seed=seed + 1)
    pcfg0 = PredictorConfig(n_bins=24, bin_max=float(lens_rep.max() + 8),
                            epochs=25, batch_size=32)
    edges0 = B.make_edges(pcfg0.n_bins, pcfg0.bin_max)
    static = train_predictor(jax.random.PRNGKey(seed + 2), jnp.asarray(phi0),
                             T.dist_target(jnp.asarray(lens_rep, jnp.float32),
                                           edges0), pcfg0, edges0)
    static_pred = np.asarray(static.predict(jnp.asarray(phi0)))

    # online remaining-length head; held-out split over PROMPTS
    phi_t, rem, ts, b_idx = online.build_online_dataset(
        out["step_hidden"], out["step_valid"], lens)
    train_m = b_idx < (n_prompts * 3) // 4
    test_m = ~train_m
    pcfg = PredictorConfig(n_bins=24, bin_max=float(rem.max() + 4), epochs=25,
                           batch_size=64)
    head = online.train_online_predictor(jax.random.PRNGKey(seed + 3),
                                         phi_t[train_m], rem[train_m], pcfg)
    report = online.evaluate_by_progress(
        head, phi_t[test_m], rem[test_m], ts[test_m],
        static_total_pred=static_pred[b_idx[test_m]])
    if verbose:
        for lo in sorted(report["online"]):
            s = report["static"].get(lo)
            print(f"  t≥{lo:3d}: online MAE {report['online'][lo]:6.2f}  "
                  f"static {s:6.2f}  (n={report['count'][lo]})" if s is not None
                  else f"  t≥{lo:3d}: online MAE {report['online'][lo]:6.2f}")
    return report


def validate(report) -> dict:
    buckets = sorted(report["online"])
    first, last = buckets[0], buckets[-1]
    checks = {
        "online_error_shrinks_with_progress":
            report["online"][last] < report["online"][first],
    }
    if report["static"]:
        on = np.mean([report["online"][b] for b in buckets])
        st = np.mean([report["static"][b] for b in buckets])
        checks["online_beats_static_remaining"] = bool(on < st)
        checks["online_avg_mae"] = float(on)
        checks["static_avg_mae"] = float(st)
    return checks


def main():
    rep = run()
    print("checks:", validate(rep))
    return rep


if __name__ == "__main__":
    main()
