"""Figure 1 / Appendix A.4 — the key observations.

(a) prompt-level median-centered noise radius across the 8 settings
    (validated against the paper's reported medians),
(b/c) heavy-tail diagnostics: max/median ratios over 100-repeat pools and the
    Hill tail-index, light5/heavy5 split.
"""

from __future__ import annotations

import numpy as np

from repro.core import metrics as M
from repro.data import make_scenario
from repro.data.lengths import sample_lengths, sample_prompt_latents
from repro.data.scenarios import MODELS, SCENARIOS, get_spec


def run(seed=0, n_noise=1200, verbose=True):
    out = {}
    for model in MODELS:
        for scen in SCENARIOS:
            spec = get_spec(model, scen)
            rng = np.random.default_rng(seed)
            lat = sample_prompt_latents(rng, spec.law, n_noise)
            L16 = sample_lengths(rng, lat, 16, spec.law)
            mm = np.asarray(M.median_mae_per_prompt(L16))
            # heavy-tail pool: 10 frozen prompts x 100 repeats (paper A.4)
            lat10 = sample_prompt_latents(rng, spec.law, 10)
            L100 = sample_lengths(rng, lat10, 100, spec.law)
            m2m = np.sort(np.asarray(M.max_to_median(L100)))
            out[(model, scen)] = {
                "noise_radius_median": float(np.median(mm)),
                "noise_radius_mean": float(np.mean(mm)),
                "noise_radius_p90": float(np.quantile(mm, 0.9)),
                "noise_ratio_median": float(np.median(np.asarray(M.noise_ratio(L16)))),
                "paper_noise_radius": spec.paper_noise_radius,
                "light5_max_to_median": float(np.mean(m2m[:5])),
                "heavy5_max_to_median": float(np.mean(m2m[5:])),
                "hill_tail_index": M.hill_tail_index(L100),
            }
            if verbose:
                o = out[(model, scen)]
                print(f"  [{model}/{scen}] radius med={o['noise_radius_median']:5.1f} "
                      f"(paper {o['paper_noise_radius']:5.1f}) "
                      f"heavy5 max/med={o['heavy5_max_to_median']:.2f} "
                      f"hill α={o['hill_tail_index']:.2f}")
    return out


def system_prompt_effect(seed=0, n=500, r=16, verbose=True):
    """Appendix A.3 analog: a fixed system prompt regularizes generations —
    modeled as a body-σ/tail-weight reduction (the paper measures ~the same
    on MBPP/Qwen: mean length down, variance down, Median-MAE left-shifted).
    Reports the noise-radius shift and the headroom it buys a predictor."""
    from dataclasses import replace
    spec = get_spec("qwen", "coding")
    law_no = replace(spec.law, sigma_body=spec.law.sigma_body * 1.35,
                     tail_weight=spec.law.tail_weight * 1.8)
    law_sys = spec.law
    rng = np.random.default_rng(seed)
    out = {}
    for name, law in (("without_system_prompt", law_no),
                      ("with_system_prompt", law_sys)):
        lat = sample_prompt_latents(rng, law, n)
        L = sample_lengths(rng, lat, r, law)
        mm = np.asarray(M.median_mae_per_prompt(L))
        out[name] = {"median_mae_median": float(np.median(mm)),
                     "median_mae_mean": float(np.mean(mm)),
                     "mean_len": float(np.mean(L))}
        if verbose:
            print(f"  {name:24s} Median-MAE med={np.median(mm):6.1f} "
                  f"mean={np.mean(mm):6.1f}")
    out["radius_reduction_pct"] = 100 * (
        1 - out["with_system_prompt"]["median_mae_median"]
        / out["without_system_prompt"]["median_mae_median"])
    return out


def validate(out) -> dict:
    checks = {}
    rel_errs = [abs(v["noise_radius_median"] - v["paper_noise_radius"])
                / v["paper_noise_radius"] for v in out.values()]
    checks["calibration_within_25pct"] = bool(max(rel_errs) < 0.25)
    checks["max_calibration_rel_err"] = float(max(rel_errs))
    checks["heavy_tails_present"] = bool(
        min(v["heavy5_max_to_median"] for v in out.values()) > 1.3)
    checks["nontrivial_noise_ratio"] = bool(
        min(v["noise_ratio_median"] for v in out.values()) > 0.08)
    return checks


def main():
    out = run()
    print("checks:", validate(out))
    return out


if __name__ == "__main__":
    main()
