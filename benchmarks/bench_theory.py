"""Theorem 1 / Lemma 3 numerical validation (paper §2.3, App. B).

Reports: Lemma-3 moment ratio, the exponential failure-probability term vs r,
the r* = 8·log(4N/δ) threshold, and empirical coverage of the pointwise bound
under median-of-r vs single-draw labels.
"""

from __future__ import annotations

import numpy as np

from repro.core import theory as TH
from repro.data.synthetic import surrogate_linear_data


def run(verbose=True):
    out = {}
    # Lemma 3 across tail weights
    lemma3 = {}
    for eps in (0.25, 0.5, 1.0):
        base, med = TH.lemma3_moment(
            lambda rng, s: rng.standard_t(1 + 2 * eps, size=s), r=16, eps=eps,
            n_trials=60000)
        lemma3[eps] = {"E|X|^{1+eps}": base, "E|med_r|^{1+eps}": med,
                       "ratio": med / base}
    out["lemma3"] = lemma3

    # failure probability vs r
    N = 2000
    out["failure_prob"] = {r: TH.failure_prob(N, r) for r in (8, 16, 32, 64, 96)}
    out["r_required_delta_0.05"] = TH.r_required(N, 0.05)

    # estimation error: single vs median labels (20 trials)
    errs_s, errs_m = [], []
    for t in range(20):
        phi, eta, theta = surrogate_linear_data(800, 8, eps=0.5, v=1.0, r=16,
                                                seed=t)
        y = phi @ theta
        errs_s.append(np.linalg.norm(TH.ridge_fit(phi, y + eta[:, 0]).theta - theta))
        errs_m.append(np.linalg.norm(
            TH.ridge_fit(phi, y + np.median(eta, axis=1)).theta - theta))
    out["ridge_err_single"] = (float(np.mean(errs_s)), float(np.std(errs_s)))
    out["ridge_err_median"] = (float(np.mean(errs_m)), float(np.std(errs_m)))

    # coverage of the Theorem-1 bound at r >= r*
    N2, d, eps, v, S, delta, lam = 600, 6, 0.5, 1.0, 1.0, 0.1, 1.0
    r_star = TH.r_required(N2, delta)
    phi, eta, theta = surrogate_linear_data(N2, d, eps=eps, v=v, r=r_star, seed=7)
    fit = TH.ridge_fit(phi, phi @ theta + np.median(eta, axis=1), lam=lam)
    beta = TH.theorem1_beta(N2, d, v, eps, delta, lam, S)
    out["coverage_at_r_star"] = TH.empirical_coverage(fit, phi, phi @ theta, beta)
    if verbose:
        print(f"  lemma3 ratios: { {k: round(v['ratio'],3) for k,v in lemma3.items()} }")
        print(f"  ridge err single={out['ridge_err_single'][0]:.4f} "
              f"median={out['ridge_err_median'][0]:.4f}")
        print(f"  r*={out['r_required_delta_0.05']} coverage={out['coverage_at_r_star']:.3f}")
    return out


def validate(out) -> dict:
    return {
        "lemma3_bound_holds": all(v["ratio"] <= 2.05 for v in out["lemma3"].values()),
        "median_labels_reduce_error": out["ridge_err_median"][0]
        < out["ridge_err_single"][0],
        "coverage_ge_1_minus_2delta": out["coverage_at_r_star"] >= 0.8,
        "failure_prob_monotone": all(
            a > b for a, b in zip(list(out["failure_prob"].values())[:-1],
                                  list(out["failure_prob"].values())[1:])),
    }


def main():
    out = run()
    print("checks:", validate(out))
    return out


if __name__ == "__main__":
    main()
