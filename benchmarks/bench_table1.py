"""Table 1 — prompt-only length prediction (main result).

Test MAE vs the 16-sample median target for every method across the eight
(served model × scenario) settings, plus the Noise Radius reference line.
Validates the paper's claims: ProD-D < ProD-M < TRAIL-last < others, and the
ProD average advantage over the best external baseline.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import all_settings, scenario_pcfg
from repro.core.baselines import METHODS, run_method
from repro.core.metrics import noise_radius


def run(fast=True, seed=0, verbose=True):
    rows = {}
    radii = {}
    for model, scen, data, epochs in all_settings(fast=fast, seed=seed):
        pcfg = scenario_pcfg(data, epochs=epochs)
        key = jax.random.PRNGKey(seed)
        for i, method in enumerate(METHODS):
            res = run_method(jax.random.fold_in(key, i), data, method, pcfg,
                             supervision="repeat", eval_target="median")
            rows.setdefault(method, {})[(model, scen)] = res.test_mae
        radii[(model, scen)] = noise_radius(data.len_test)
        if verbose:
            print(f"  [{model}/{scen}] " + "  ".join(
                f"{m}={rows[m][(model, scen)]:.1f}" for m in METHODS))

    table = {}
    for method in METHODS:
        for model in ("qwen", "llama"):
            vals = [rows[method][(model, s)] for _, s in
                    [(model, sc) for sc in ("math", "coding", "longseq", "chat")]]
            table[(method, model, "avg")] = float(np.mean(vals))
    checks = validate(rows, radii)
    return {"rows": rows, "noise_radius": radii, "avg": table, "checks": checks}


def validate(rows, radii) -> dict:
    """The paper's qualitative claims on Table 1:
    ProD-D strictly best on average (both backbones); ProD-M at worst ties the
    strongest external baseline (the paper's own gap is ~5%); the informative
    views beat Constant-Median; EGTP is allowed to underperform — the paper
    itself reports it losing to Constant on qwen/chat ("entropy-weighted
    selection concentrates on early tokens")."""
    settings = list(rows["prod_d"].keys())
    avg = lambda m: float(np.mean([rows[m][s] for s in settings]))
    externals = ("s3", "trail_mean", "trail_last", "egtp")
    checks = {
        "prod_d_best_avg": avg("prod_d") <= min(
            avg(m) for m in rows if m != "prod_d") + 1e-9,
        "prod_m_at_worst_ties_best_external": avg("prod_m")
        <= 1.03 * min(avg(m) for m in externals),
        "prod_beats_trail_last_pct": 100.0 * (avg("trail_last") - avg("prod_d"))
        / avg("trail_last"),
        "informative_views_beat_constant": all(
            avg(m) < avg("constant_median")
            for m in ("trail_mean", "trail_last", "prod_m", "prod_d")),
        "egtp_underperforms": avg("egtp") > avg("trail_last"),
    }
    return checks


def main(fast=True):
    out = run(fast=fast)
    print("\nTable 1 averages (test MAE, lower better):")
    for (method, model, _), v in sorted(out["avg"].items()):
        print(f"  {method:16s} {model:6s} {v:8.2f}")
    print("claims:", out["checks"])
    return out


if __name__ == "__main__":
    main()
