"""Shared benchmark helpers: scenario construction, method runs, CSV rows."""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.common.config import PredictorConfig
from repro.core.baselines import run_method
from repro.core import metrics as M
from repro.data import make_scenario
from repro.data.scenarios import MODELS, SCENARIOS

# CPU-friendly defaults; --full switches to the paper's split sizes
FAST = dict(n_train=700, n_test=350, epochs=15)
FULL = dict(n_train=None, n_test=None, epochs=30, full_paper_splits=True)


def scenario_pcfg(data, n_bins=64, epochs=15) -> PredictorConfig:
    bin_max = float(np.quantile(data.len_train, 0.999) * 1.3)
    return PredictorConfig(n_bins=n_bins, bin_max=bin_max, epochs=epochs)


def all_settings(fast=True, seed=0):
    prof = FAST if fast else FULL
    for model in MODELS:
        for scen in SCENARIOS:
            data = make_scenario(
                model, scen, seed=seed,
                n_train=prof.get("n_train"), n_test=prof.get("n_test"),
                full_paper_splits=prof.get("full_paper_splits", False),
            )
            yield model, scen, data, prof["epochs"]


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
