"""Kernel micro-benchmarks: µs/call of the XLA reference path on CPU (the
compiled-TPU path is exercised via the dry-run) + interpret-mode allclose."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))  # compile + warm up
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose=True):
    rows = {}
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    # flash attention (xla path)
    B, S, H, KV, hd = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="xla"))
    rows["flash_attention_512"] = _time(f, q, k, v)

    # decode attention
    Sc = 4096
    qd = jax.random.normal(ks[3], (8, H, hd))
    kc = jax.random.normal(ks[4], (8, Sc, KV, hd))
    vc = jax.random.normal(ks[5], (8, Sc, KV, hd))
    lens = jnp.full((8,), Sc, jnp.int32)
    fd = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l, impl="xla"))
    rows["decode_attention_4k"] = _time(fd, qd, kc, vc, lens)

    # ssd scan
    x = jax.random.normal(ks[6], (2, 512, 8, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (2, 512, 8)))
    a = -dt * 0.5
    Bm = jax.random.normal(ks[0], (2, 512, 32))
    Cm = jax.random.normal(ks[1], (2, 512, 32))
    fs = jax.jit(lambda *args: ops.ssd_scan(*args, impl="xla"))
    rows["ssd_scan_512"] = _time(fs, x, dt, a, Bm, Cm)

    # prod head (the paper's serving-path addition — should be trivial)
    phi = jax.random.normal(ks[2], (128, 1024))
    w1 = jax.random.normal(ks[3], (1024, 512)) * 0.05
    w2 = jax.random.normal(ks[4], (512, 64)) * 0.05
    edges = jnp.linspace(0, 8192.0, 65)
    fp = jax.jit(lambda p: ops.prod_head(p, w1, jnp.zeros(512), w2,
                                         jnp.zeros(64), edges, impl="xla"))
    rows["prod_head_128x1024"] = _time(fp, phi)

    if verbose:
        for name, us in rows.items():
            print(f"  {name:24s} {us:10.1f} us/call")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
