"""Benchmark driver — one entry per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows (spec format). Usage:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale splits (slow; default is CPU-fast)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,theory,table1,table23,fig2,serving,online,bins,kernels,roofline")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None
    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        print(f"{name},{value},{derived}", flush=True)

    def want(name):
        return only is None or name in only

    t_all = time.time()

    if want("fig1"):
        print("== Figure 1 / A.4: key observations ==", flush=True)
        from benchmarks import bench_fig1
        out = bench_fig1.run()
        checks = bench_fig1.validate(out)
        emit("fig1_calibration_max_rel_err", f"{checks['max_calibration_rel_err']:.3f}",
             "vs paper noise radii")
        emit("fig1_heavy_tails_present", checks["heavy_tails_present"], "")
        sp = bench_fig1.system_prompt_effect()
        emit("fig1_system_prompt_radius_reduction_pct",
             f"{sp['radius_reduction_pct']:.1f}", "A.3 analog")

    if want("theory"):
        print("== Theorem 1 / Lemma 3 ==", flush=True)
        from benchmarks import bench_theory
        out = bench_theory.run()
        checks = bench_theory.validate(out)
        for k, v in checks.items():
            emit(f"theory_{k}", v, "")
        emit("theory_lemma3_worst_ratio",
             f"{max(v['ratio'] for v in out['lemma3'].values()):.3f}", "bound: 2.0")

    t1_rows = None
    if want("table1"):
        print("== Table 1: prompt-only MAE ==", flush=True)
        from benchmarks import bench_table1
        out = bench_table1.run(fast=fast)
        t1_rows = out["rows"]
        for (method, model, _), v in sorted(out["avg"].items()):
            emit(f"table1_avg_{method}_{model}", f"{v:.2f}", "MAE tokens")
        for k, v in out["checks"].items():
            emit(f"table1_{k}", v if not isinstance(v, float) else f"{v:.1f}", "")

    if want("table23"):
        print("== Tables 2-3: single-sample ablation ==", flush=True)
        from benchmarks import bench_table23
        out = bench_table23.run(fast=fast)
        if t1_rows is not None:
            checks = bench_table23.validate(out, t1_rows)
            for k, v in checks.items():
                emit(f"table23_{k}", v if not isinstance(v, float) else f"{v:.1f}", "")

    if want("fig2"):
        print("== Figure 2: budget fairness ==", flush=True)
        from benchmarks import bench_fig2
        out = bench_fig2.run(fast=fast)
        for k, v in bench_fig2.validate(out).items():
            emit(f"fig2_{k}", v, "")

    if want("serving"):
        print("== Serving impact (beyond paper) ==", flush=True)
        from benchmarks import bench_serving
        srows = bench_serving.run(fast=fast)
        for k, v in bench_serving.validate(srows).items():
            emit(f"serving_{k}", v if not isinstance(v, float) else f"{v:.1f}", "")

    if want("online"):
        print("== ProD-O: online remaining-length (beyond paper) ==", flush=True)
        from benchmarks import bench_online
        rep = bench_online.run()
        for k, v in bench_online.validate(rep).items():
            emit(f"online_{k}", v if not isinstance(v, float) else f"{v:.2f}", "")

    if want("bins"):
        print("== Bin-spacing ablation (beyond paper) ==", flush=True)
        from benchmarks import bench_bins_ablation
        out = bench_bins_ablation.run(fast=fast)
        for k, v in bench_bins_ablation.validate(out).items():
            emit(f"bins_{k}", v, "")

    if want("kernels"):
        print("== Kernel micro-benchmarks ==", flush=True)
        from benchmarks import bench_kernels
        for name, us in bench_kernels.run().items():
            emit(name, f"{us:.1f}", "us_per_call (xla/cpu)")

    if want("roofline"):
        print("== Roofline (from dry-run artifacts) ==", flush=True)
        from benchmarks import roofline
        rrows = roofline.load()
        ok = sum(1 for r in rrows if r.get("ok"))
        emit("roofline_pod_combos_ok", f"{ok}/{len(rrows)}", "lower+compile on 16x16")
        mrows = roofline.load(mesh="multipod")
        mok = sum(1 for r in mrows if r.get("ok"))
        emit("roofline_multipod_combos_ok", f"{mok}/{len(mrows)}", "2x16x16")

    print(f"\ntotal bench time: {time.time()-t_all:.0f}s ({len(rows)} rows)")


if __name__ == "__main__":
    main()
